//! Minimal JSON support (parse + serialize).
//!
//! The vendored dependency set has no `serde`/`serde_json`, so the artifact
//! manifests, weight headers, server protocol and bench reports use this
//! small self-contained implementation. It supports the full JSON value
//! model with the restrictions we need: numbers are f64, object keys are
//! unique (last wins).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset from the JSON parser.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with decent error messages.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field '{key}'"))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`json.to_string()` via `Display`).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Containers deeper than this parse as an error instead of risking a
/// stack overflow (the parser is recursive-descent; untrusted wire
/// bytes flow through it).
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: accept and combine when possible.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                    continue;
                                }
                            }
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.i - 1;
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let r = self.array_inner();
        self.depth -= 1;
        r
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let r = self.object_inner();
        self.depth -= 1;
        r
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_f64(),
            Some(-2500.0)
        );
        // Round trip parses back to the same value.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".to_string()));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("n", 5usize.into()).set("s", "hi".into());
        assert_eq!(o.req_usize("n").unwrap(), 5);
        assert_eq!(o.req_str("s").unwrap(), "hi");
        assert!(o.req_str("missing").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse(" { } ").unwrap().to_string(), "{}");
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // Hostile wire bytes: 50k unclosed arrays must not recurse 50k
        // frames deep.
        assert!(Json::parse(&"[".repeat(50_000)).is_err());
        let deep_obj = "{\"k\":".repeat(50_000);
        assert!(Json::parse(&deep_obj).is_err());
        // Reasonable nesting still parses.
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
    }
}
