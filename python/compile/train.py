"""Build-time training of the tiny char-LMs (the Figure-3 model substitutes).

Hand-rolled Adam (the environment has no optax) with cosine decay and
linear warmup; next-byte cross entropy on the synthetic corpus of
`data.py`. Runs once under `make artifacts` and never at serving time.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from . import data as data_mod
from . import model as model_mod


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    new_params = {
        k: params[k] - lr * (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps) for k in params
    }
    return new_params, {"m": m, "v": v, "t": t}


def lr_schedule(step, steps, peak):
    warmup = max(1, steps // 10)
    lin = peak * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / max(1, steps - warmup), 0.0, 1.0)
    cos = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, lin, cos)


def train(
    cfg: model_mod.ModelConfig,
    *,
    seed: int = 0,
    steps: int = 300,
    seq_len: int = 192,
    batch_size: int = 12,
    corpus_bytes: int = 400_000,
    peak_lr: float = 3e-3,
    log_every: int = 50,
):
    """Train and return (params, final_loss_history)."""
    corpus = data_mod.corpus_bytes(seed, corpus_bytes)
    params = model_mod.init_params(cfg, seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(model_mod.loss_fn)(params, cfg, x, y)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    losses = []
    t0 = time.time()
    for step, (x, y) in enumerate(
        data_mod.batches(corpus, seq_len, batch_size, steps, seed + 1)
    ):
        lr = lr_schedule(jnp.float32(step), steps, peak_lr)
        params, opt, loss = step_fn(params, opt, jnp.asarray(x), jnp.asarray(y), lr)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(
                f"[train {cfg.name}] step {step:4d}/{steps} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, losses
