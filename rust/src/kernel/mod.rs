//! Hardware-efficient kernel layer.
//!
//! The paper's speedup story is "evaluate attention only on the
//! HSR-reported set" — which only pays off if the per-entry evaluation is
//! itself hardware-efficient (the lesson of the SparseAccelerate /
//! SampleAttention line of work). This module is that layer:
//!
//! * [`simd`] — runtime-dispatched 8-lane f32 micro-kernels (dot,
//!   blocked dense scoring, gathered subset scoring, axpy, fused
//!   max/sum-exp) with an AVX2+FMA path on x86_64 and a portable
//!   unrolled fallback. Dispatch is detected once and cached; scalar
//!   twins are exported for property tests and before/after benches.
//! * [`scratch`] — the reusable per-thread [`Scratch`] arena (fire /
//!   scores / selected / exp buffers) threaded through decode, prefill
//!   and serving so the per-row inner loops perform no heap allocation.
//!
//! Layering: `hsr`, `attention`, `engine` and `model` all call down into
//! this module; nothing here calls up. Every inner product in the crate
//! (HSR pruning tests, leaf scans, score gathers, value accumulations,
//! softmax rows) routes through these entry points, so a new ISA path
//! added here accelerates every layer at once.

pub mod scratch;
pub mod simd;

pub use scratch::Scratch;
