//! Byte-level tokenizer: the model's vocabulary is exactly the 256 byte
//! values (matching `python/compile/model.py`'s VOCAB_SIZE = 256). Kept
//! as a type so the serving API has a stable encode/decode boundary.

/// Byte-level tokenizer (identity over bytes).
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Decode tokens to text, replacing invalid UTF-8 with U+FFFD.
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "the merchant carries copper coins.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn tokens_are_bytes() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("AB"), vec![65, 66]);
        assert!(t.encode("é").iter().all(|&x| x < 256));
    }

    #[test]
    fn invalid_utf8_is_replaced() {
        let t = ByteTokenizer;
        let s = t.decode(&[0xFF, 65]);
        assert!(s.ends_with('A'));
    }
}
