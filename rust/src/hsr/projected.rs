//! Projection-augmented HSR for high-dimensional, anisotropic keys.
//!
//! The AEM92 bounds degrade to ~linear queries as d grows (Part 1 is
//! O(n^{1-1/⌊d/2⌋}): already 97% of linear at d = 64), and isotropic
//! Gaussian clouds in high d admit essentially no exact pruning (measured
//! in `balltree.rs`). Real attention keys, however, are *strongly
//! anisotropic* — the massive-activation literature the paper builds on
//! ([SCKL24] etc.) shows the score mass concentrates in a few directions.
//! `ProjectedHsr` exploits that while staying **exact**:
//!
//! 1. Compute the top-c principal directions P ∈ R^{c×d} of the key set
//!    (power iteration + deflation — no LAPACK dependency).
//! 2. Index each key as the (c+1)-dim point (P·k_i, ‖k_i − PᵀP·k_i‖) in a
//!    ball tree.
//! 3. For query (a, b): by Cauchy–Schwarz,
//!       <a, k_i> = <P·a, P·k_i> + <r_a, r_i>  ≤  <P·a, P·k_i> + ‖r_a‖·‖r_i‖,
//!    so querying the inner tree with direction (P·a, ‖r_a‖) and the same
//!    threshold b yields a **superset** of the true report set; a final
//!    exact filter over the candidates removes false positives.
//!
//! No false negatives are possible, so the structure is exact for any key
//! distribution; the candidate count (and hence query time) degrades
//! gracefully toward brute force as anisotropy disappears.

use super::{balltree::BallTreeHsr, dot, HalfSpaceReport, QueryStats};

/// Number of power-iteration rounds per principal direction.
const POWER_ITERS: usize = 12;

/// Exact HSR over high-d points via projection + residual augmentation.
pub struct ProjectedHsr {
    /// Original points, row-major (for the exact filter).
    points: Vec<f32>,
    n: usize,
    d: usize,
    /// Projection matrix, c rows of length d (orthonormal).
    proj: Vec<f32>,
    c: usize,
    /// Inner tree over (c+1)-dim augmented points.
    inner: BallTreeHsr,
}

impl ProjectedHsr {
    /// Build with `c` principal directions (clamped to d). O(n·d·c) build
    /// on top of the inner ball-tree's O(n log n).
    pub fn build(points: &[f32], d: usize, c: usize) -> ProjectedHsr {
        assert!(d > 0);
        assert_eq!(points.len() % d, 0);
        let n = points.len() / d;
        let c = c.clamp(1, d);
        let proj = principal_directions(points, n, d, c);
        // Augmented points: (P x_i, ||residual_i||).
        let mut aug = Vec::with_capacity(n * (c + 1));
        for i in 0..n {
            let x = &points[i * d..(i + 1) * d];
            let mut px = vec![0f32; c];
            for (j, p) in proj.chunks_exact(d).enumerate() {
                px[j] = dot(p, x);
            }
            // residual^2 = ||x||^2 - ||Px||^2  (P orthonormal).
            let res2 = (dot(x, x) - dot(&px, &px)).max(0.0);
            aug.extend_from_slice(&px);
            aug.push(res2.sqrt());
        }
        let inner = BallTreeHsr::build(&aug, c + 1);
        ProjectedHsr { points: points.to_vec(), n, d, proj, c, inner }
    }

    /// Fraction of total variance captured by the projection (diagnostic).
    pub fn captured_variance(&self) -> f64 {
        let mut total = 0f64;
        let mut captured = 0f64;
        for i in 0..self.n {
            let x = &self.points[i * self.d..(i + 1) * self.d];
            total += dot(x, x) as f64;
            for p in self.proj.chunks_exact(self.d) {
                let v = dot(p, x) as f64;
                captured += v * v;
            }
        }
        if total == 0.0 {
            1.0
        } else {
            captured / total
        }
    }
}

impl HalfSpaceReport for ProjectedHsr {
    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        self.query_filtered(a, b, out, None, stats);
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        self.query_filtered(a, b, out, Some(scores), stats);
    }

    /// Native shared traversal: all augmented queries walk the inner
    /// ball tree once (unscored — candidate scores in the augmented
    /// space are useless to the exact filter), then each query's
    /// candidate set is filtered exactly, with per-(query, candidate)
    /// counting identical to the single-query path.
    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        let d = self.d;
        let q = bs.len();
        assert_eq!(queries.len(), q * d);
        assert_eq!(outs.len(), q);
        assert_eq!(scores.len(), q);
        if self.n == 0 || q == 0 {
            return;
        }
        MANY_SCRATCH.with(|cell| {
            let (aug, candidates) = &mut *cell.borrow_mut();
            // Augmented query block (P a_i, ‖residual_{a_i}‖), row-major.
            let ad = self.c + 1;
            aug.clear();
            aug.resize(q * ad, 0.0);
            for i in 0..q {
                let a = &queries[i * d..(i + 1) * d];
                let qa = &mut aug[i * ad..(i + 1) * ad];
                for (j, p) in self.proj.chunks_exact(d).enumerate() {
                    qa[j] = dot(p, a);
                }
                let head2 = dot(&qa[..self.c], &qa[..self.c]);
                qa[self.c] = (dot(a, a) - head2).max(0.0).sqrt();
            }
            // Shared superset traversal; the inner tree's report counters
            // refer to candidates, not true reports — restore them and
            // let the exact filter below do the counting.
            while candidates.len() < q {
                candidates.push(Vec::new());
            }
            for c in candidates.iter_mut().take(q) {
                c.clear();
            }
            let (reported_before, bulk_before) = (stats.reported, stats.bulk_reported);
            self.inner.query_many_impl(aug, bs, &mut candidates[..q], None, stats);
            stats.reported = reported_before;
            stats.bulk_reported = bulk_before;
            for i in 0..q {
                let a = &queries[i * d..(i + 1) * d];
                for &j in candidates[i].iter() {
                    stats.points_scanned += 1;
                    let x = &self.points[j as usize * d..(j as usize + 1) * d];
                    let s = dot(x, a);
                    if s >= bs[i] {
                        outs[i].push(j);
                        scores[i].push(s);
                        stats.reported += 1;
                    }
                }
            }
        });
    }
}

thread_local! {
    /// Per-thread (augmented-query-block, per-query candidate) buffers
    /// for the shared-traversal path — same zero-allocation discipline
    /// as the single-query `QUERY_SCRATCH`, same reentrancy argument.
    static MANY_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<Vec<u32>>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

thread_local! {
    /// Per-thread reusable (augmented-query, candidate) buffers so the
    /// decode/prefill inner loops stay allocation-free. Reentrancy-safe:
    /// the inner structure is a ball tree, which never queries back into
    /// a `ProjectedHsr`.
    static QUERY_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<u32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

impl ProjectedHsr {
    fn query_filtered(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        mut scores: Option<&mut Vec<f32>>,
        stats: &mut QueryStats,
    ) {
        assert_eq!(a.len(), self.d);
        if self.n == 0 {
            return;
        }
        QUERY_SCRATCH.with(|cell| {
            let (qa, candidates) = &mut *cell.borrow_mut();
            // Build the augmented query (P a, ||residual_a||).
            qa.clear();
            qa.resize(self.c + 1, 0.0);
            for (j, p) in self.proj.chunks_exact(self.d).enumerate() {
                qa[j] = dot(p, a);
            }
            let head2 = dot(&qa[..self.c], &qa[..self.c]);
            qa[self.c] = (dot(a, a) - head2).max(0.0).sqrt();
            // Superset query on the inner structure, then exact filter.
            // The inner tree's reported/bulk counters refer to candidates,
            // not true reports: restore them and count the filter output.
            let (reported_before, bulk_before) = (stats.reported, stats.bulk_reported);
            candidates.clear();
            self.inner.query_into(qa, b, candidates, stats);
            stats.reported = reported_before;
            stats.bulk_reported = bulk_before;
            for &i in candidates.iter() {
                stats.points_scanned += 1;
                let x = &self.points[i as usize * self.d..(i as usize + 1) * self.d];
                let s = dot(x, a);
                if s >= b {
                    out.push(i);
                    if let Some(sc) = scores.as_mut() {
                        sc.push(s);
                    }
                    stats.reported += 1;
                }
            }
        });
    }
}

/// Top-c principal directions of the (uncentered) second-moment matrix via
/// power iteration with deflation. Uncentered is the right notion here:
/// the half-space test is about raw inner products, not centered ones.
fn principal_directions(points: &[f32], n: usize, d: usize, c: usize) -> Vec<f32> {
    let mut dirs: Vec<f32> = Vec::with_capacity(c * d);
    // Deterministic seed vectors.
    let mut rng = crate::util::rng::Rng::new(0x9d_1c_e5);
    for _ in 0..c {
        let mut v = rng.gaussian_vec_f32(d, 1.0);
        normalize(&mut v);
        for _ in 0..POWER_ITERS {
            // w = (1/n) Σ x <x, v>, then deflate and normalize.
            let mut w = vec![0f32; d];
            for i in 0..n {
                let x = &points[i * d..(i + 1) * d];
                let s = dot(x, &v);
                for (wj, &xj) in w.iter_mut().zip(x) {
                    *wj += s * xj;
                }
            }
            deflate(&mut w, &dirs, d);
            if !normalize(&mut w) {
                break; // rank-deficient: keep previous v
            }
            v = w;
        }
        deflate(&mut v, &dirs, d);
        if !normalize(&mut v) {
            // Fall back to a coordinate direction not yet covered.
            v = vec![0f32; d];
            v[dirs.len() / d % d] = 1.0;
            deflate(&mut v, &dirs, d);
            normalize(&mut v);
        }
        dirs.extend_from_slice(&v);
    }
    dirs
}

fn deflate(v: &mut [f32], dirs: &[f32], d: usize) {
    for p in dirs.chunks_exact(d) {
        let s = dot(p, v);
        for (vj, &pj) in v.iter_mut().zip(p) {
            *vj -= s * pj;
        }
    }
}

fn normalize(v: &mut [f32]) -> bool {
    let nrm = super::norm(v);
    if nrm < 1e-12 {
        return false;
    }
    for x in v.iter_mut() {
        *x /= nrm;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::reference_query;
    use crate::util::rng::Rng;

    /// Draw anisotropic Gaussians: a few dominant directions (as in real
    /// attention key caches) plus isotropic noise.
    fn anisotropic(rng: &mut Rng, n: usize, d: usize, heavy: usize, scale: f64) -> Vec<f32> {
        let mut pts = vec![0f32; n * d];
        for i in 0..n {
            for j in 0..d {
                let sigma = if j < heavy { scale } else { 0.3 };
                pts[i * d + j] = rng.normal(0.0, sigma) as f32;
            }
        }
        pts
    }

    #[test]
    fn exact_on_isotropic() {
        let mut rng = Rng::new(41);
        for _ in 0..15 {
            let d = rng.range(3, 24);
            let n = rng.range(1, 400);
            let pts = rng.gaussian_vec_f32(n * d, 1.0);
            let h = ProjectedHsr::build(&pts, d, 4);
            for _ in 0..4 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.5, 1.0) as f32;
                assert_eq!(h.query(&a, b), reference_query(&pts, d, &a, b));
            }
        }
    }

    #[test]
    fn exact_on_anisotropic() {
        let mut rng = Rng::new(43);
        let (n, d) = (2_000usize, 32usize);
        let pts = anisotropic(&mut rng, n, d, 3, 3.0);
        let h = ProjectedHsr::build(&pts, d, 4);
        assert!(h.captured_variance() > 0.8, "pca failed: {}", h.captured_variance());
        for _ in 0..10 {
            let a = rng.gaussian_vec_f32(d, 1.0);
            let b = rng.normal(1.0, 2.0) as f32;
            assert_eq!(h.query(&a, b), reference_query(&pts, d, &a, b));
        }
    }

    #[test]
    fn prunes_on_anisotropic_high_d() {
        // The whole point of this structure: at d = 64 with concentrated
        // score directions, candidate counts collapse far below n.
        let mut rng = Rng::new(47);
        let (n, d) = (20_000usize, 64usize);
        let pts = anisotropic(&mut rng, n, d, 4, 4.0);
        let h = ProjectedHsr::build(&pts, d, 6);
        let mut total_scanned = 0usize;
        let trials = 10;
        for _ in 0..trials {
            // Queries aligned with the heavy subspace (like trained q/k).
            let mut a = vec![0f32; d];
            for j in 0..4 {
                a[j] = rng.normal(0.0, 4.0) as f32;
            }
            for x in a.iter_mut().skip(4) {
                *x = rng.normal(0.0, 0.3) as f32;
            }
            // Threshold near the top of the score distribution.
            let scores: Vec<f32> = (0..n).map(|i| dot(&pts[i * d..(i + 1) * d], &a)).collect();
            let mut sorted = scores.clone();
            sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
            let b = sorted[n / 100]; // top 1%
            let mut out = Vec::new();
            let mut stats = QueryStats::default();
            h.query_into(&a, b, &mut out, &mut stats);
            out.sort_unstable();
            assert_eq!(out, reference_query(&pts, d, &a, b));
            total_scanned += stats.points_scanned;
        }
        let avg = total_scanned / trials;
        assert!(avg < n / 3, "avg candidates {avg} of n={n} — projection not pruning");
    }

    #[test]
    fn handles_duplicate_and_zero_points() {
        let pts = vec![0f32; 10 * 8];
        let h = ProjectedHsr::build(&pts, 8, 3);
        assert_eq!(h.query(&[1.0; 8], -0.5).len(), 10);
        assert_eq!(h.query(&[1.0; 8], 0.5).len(), 0);
    }
}
