//! Attention mathematics: the paper's two mechanisms and their sparse
//! counterparts.
//!
//! * [`softmax`] — conventional Softmax attention (Definition 1.1) and
//!   Softmax attention restricted to an index set / top-r indices
//!   (Definitions B.1, B.2).
//! * [`relu`] — ReLU^α attention with threshold bias b (Definition 1.2),
//!   dense and sparse-from-indices.
//! * [`topk`] — NN(r, q, K) selection (Definition B.2).
//! * [`threshold`] — the Lemma 6.1 threshold b = σ_a·sqrt(0.4·ln n) and
//!   the predicted activated-entry counts behind Table 1.
//! * [`error`] — approximation-error machinery: the general bound of
//!   Lemma G.1, the massive-activation bound of Theorem 4.3, and a
//!   checker for the (γ, β₁, β₂) property of Definition B.3.
//! * [`session`] / [`plan`] — the unified plan→execute session API
//!   ([`AttentionConfig`] → [`AttentionSession`] → [`AttentionPlan`]):
//!   the canonical entry point every engine path drives.
//!
//! Conventions: all matrices are row-major `f32` slices; `Q` is m×d,
//! `K`/`V` are n×d, outputs are m×d. Scores are `<q, k>/sqrt(d)` exactly
//! as in Definitions 1.1/1.2.

pub mod activations;
pub mod error;
pub mod plan;
pub mod relu;
pub mod session;
pub mod softmax;
pub mod threshold;
pub mod topk;

pub use plan::AttentionPlan;
pub use session::{AttentionConfig, AttentionSession, ThresholdPolicy};

use crate::kernel::simd;

/// Which attention mechanism a component should use.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttentionKind {
    /// Softmax attention (Definition 1.1), optionally restricted to the
    /// top-r indices (Definition B.2).
    Softmax,
    /// ReLU^α attention (Definition 1.2) with threshold bias `b`.
    Relu { alpha: u32, bias: f32 },
}

/// The single score-buffer convention every scoring helper shares:
/// clear-and-size the caller's reusable `Vec` to exactly `n` entries and
/// return the writable slice. Capacity is retained across calls, so hot
/// loops that thread one buffer through stay allocation-free — and
/// session code never branches on buffer shape.
pub fn sized_scores(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(n, 0.0);
    buf
}

/// Compute one row of raw attention scores s_j = <q, K_j>/sqrt(d) via the
/// blocked SIMD scoring kernel. `scores` is caller-owned and sized here
/// (to n = keys.len() / d) through [`sized_scores`].
pub fn scores_into(q: &[f32], keys: &[f32], d: usize, scores: &mut Vec<f32>) {
    let n = keys.len() / d;
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    simd::scaled_dots_into(q, keys, d, inv_sqrt_d, sized_scores(scores, n));
}

/// Scores for a subset of key indices: s_t = <q, K_{idx_t}>/sqrt(d)
/// (gathered SIMD subset-dot kernel). Same buffer convention as
/// [`scores_into`]: caller-owned `Vec`, sized here to idx.len().
pub fn scores_subset_into(
    q: &[f32],
    keys: &[f32],
    d: usize,
    idx: &[u32],
    scores: &mut Vec<f32>,
) {
    simd::gathered_scaled_dots_into(
        q,
        keys,
        d,
        idx,
        1.0 / (d as f32).sqrt(),
        sized_scores(scores, idx.len()),
    );
}

/// out += w * V_j for a single value row.
#[inline]
pub fn axpy_row(out: &mut [f32], values: &[f32], d: usize, j: usize, w: f32) {
    simd::axpy(out, &values[j * d..(j + 1) * d], w);
}

/// Max absolute difference between two equal-length slices (the ℓ∞ metric
/// used by every error theorem in the paper).
pub fn linf(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scores_scale_by_sqrt_d() {
        let q = [2.0f32, 0.0, 0.0, 0.0];
        let keys = [3.0f32, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        let mut s = Vec::new();
        scores_into(&q, &keys, 4, &mut s);
        assert_eq!(s.len(), 2);
        assert!((s[0] - 3.0).abs() < 1e-6); // 6 / sqrt(4)
        assert!((s[1] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn subset_scores_match_dense() {
        let q = [1.0f32, -1.0];
        let keys = [1.0f32, 0.0, 0.0, 1.0, 2.0, 2.0];
        let mut dense = Vec::new();
        scores_into(&q, &keys, 2, &mut dense);
        let mut sub = Vec::new();
        scores_subset_into(&q, &keys, 2, &[2, 0], &mut sub);
        assert_eq!(sub, vec![dense[2], dense[0]]);
    }

    /// Both scoring helpers size the caller's buffer themselves (and a
    /// stale longer buffer is truncated, not appended to).
    #[test]
    fn score_buffers_are_caller_sized() {
        let q = [1.0f32, 0.0];
        let keys = [1.0f32, 0.0, 0.0, 1.0];
        let mut buf = vec![9.0f32; 17];
        scores_into(&q, &keys, 2, &mut buf);
        assert_eq!(buf.len(), 2);
        let cap = buf.capacity();
        scores_subset_into(&q, &keys, 2, &[1], &mut buf);
        assert_eq!(buf.len(), 1);
        assert_eq!(buf.capacity(), cap, "capacity must be retained");
    }

    #[test]
    fn linf_basic() {
        assert_eq!(linf(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert_eq!(linf(&[], &[]), 0.0);
    }
}
