//! Tiered KV store property tests: cold-segment spill + content dedup.
//!
//! The acceptance bar for the tier subsystem: a segment that is demoted
//! to the compressed cold tier and refaulted back must be
//! **bit-identical** to one that was never evicted — payload floats,
//! calibration snapshot, and HSR query answers alike — across every
//! backend and both `SpillPolicy` variants. Dedup must keep the block
//! ledger exact under arbitrary publish/evict/refault interleavings:
//! no double-free, no leaked block, no leaked spill extent. Like
//! `tests/prefix_cache.rs`, everything runs at `d_head <= 8` where
//! float equality is exact.

use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{GenerationParams, SchedulerConfig};
use hsr_attn::hsr::{HsrBackend, QueryStats};
use hsr_attn::kvstore::{
    Demoted, PagePool, PrefixCacheMode, PrefixStore, Refault, SpillConfig, SpillPolicy,
    TierConfig,
};
use hsr_attn::model::kv::KvState;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::util::rng::Rng;
use std::sync::Arc;

fn tier_mem(policy: SpillPolicy) -> TierConfig {
    TierConfig { spill: SpillConfig::Memory, policy }
}

/// Deterministic KV source: `rows` gaussian key/value rows per head.
fn filled_kv(
    seed: u64,
    rows: usize,
    n_layers: usize,
    n_heads: usize,
    d_head: usize,
    backend: Option<HsrBackend>,
) -> KvState {
    let mut rng = Rng::new(seed);
    let mut kv = KvState::new(n_layers, n_heads, d_head, backend);
    for _ in 0..rows {
        for l in 0..n_layers {
            for h in 0..n_heads {
                let k = rng.gaussian_vec_f32(d_head, 1.0);
                let v = rng.gaussian_vec_f32(d_head, 1.0);
                kv.head_mut(l, h).append(&k, &v);
            }
        }
    }
    kv
}

fn prompt_bytes(seed: u32, len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 11 + seed * 37 + 3) % 256).collect()
}

/// Every key/value bit and the calibration snapshot must match.
fn assert_kv_bits_equal(a: &KvState, b: &KvState, ctx: &str) {
    assert_eq!(a.heads.len(), b.heads.len(), "{ctx}: head count");
    for (i, (ha, hb)) in a.heads.iter().zip(b.heads.iter()).enumerate() {
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&ha.keys), bits(&hb.keys), "{ctx}: head {i} keys");
        assert_eq!(bits(&ha.values), bits(&hb.values), "{ctx}: head {i} values");
        assert_eq!(
            ha.calib_threshold.map(f32::to_bits),
            hb.calib_threshold.map(f32::to_bits),
            "{ctx}: head {i} calib"
        );
    }
}

/// HSR answers (fired index sets AND raw scores) must match bitwise —
/// this is what proves a rebuilt/deserialized index is equivalent, not
/// just the payload bytes.
fn assert_queries_equal(a: &KvState, b: &KvState, seed: u64, ctx: &str) {
    let mut rng = Rng::new(seed);
    for q_iter in 0..8 {
        let q = rng.gaussian_vec_f32(a.d_head, 1.0);
        let b_raw = rng.uniform(-2.0, 2.0) as f32;
        for (i, (ha, hb)) in a.heads.iter().zip(b.heads.iter()).enumerate() {
            let (mut oa, mut sa) = (Vec::new(), Vec::new());
            let (mut ob, mut sb) = (Vec::new(), Vec::new());
            let mut st = QueryStats::default();
            ha.hsr_query_scored(&q, b_raw, &mut oa, &mut sa, &mut st);
            hb.hsr_query_scored(&q, b_raw, &mut ob, &mut sb, &mut st);
            assert_eq!(oa, ob, "{ctx}: head {i} query {q_iter} fired set");
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&sa), bits(&sb), "{ctx}: head {i} query {q_iter} scores");
        }
    }
}

/// Spill → refault round-trip is bit-identical to never-evicted, for
/// every HSR backend (incl. the no-index ablation) under both spill
/// policies. `Layers2d` is 2-D-only and thus out of this matrix.
#[test]
fn spill_refault_bit_identity_all_backends_and_policies() {
    let backends = [
        Some(HsrBackend::BallTree),
        Some(HsrBackend::Projected),
        Some(HsrBackend::Brute),
        None,
    ];
    let tokens: Vec<u32> = (0..48).map(|i| (i * 7 + 1) % 256).collect();
    for backend in backends {
        for policy in [SpillPolicy::RebuildOnRefault, SpillPolicy::SerializeHsr] {
            let ctx = format!("backend={backend:?} policy={policy:?}");
            let src = filled_kv(7, 48, 2, 2, 8, backend);
            let mut never = PagePool::new(1 << 12, 16, backend);
            let id_n = never.create_segment(&tokens, 0, &src, 0).expect("fits");
            let mut tiered = PagePool::with_tier(1 << 12, 16, backend, &tier_mem(policy));
            assert!(tiered.spill_enabled());
            let id_t = tiered.create_segment(&tokens, 0, &src, 0).expect("fits");
            let free_before = tiered.free_blocks();
            assert!(tiered.can_demote(id_t), "{ctx}");
            assert_eq!(tiered.release_segment(id_t, true, false), Demoted::Spilled, "{ctx}");
            assert!(tiered.is_cold(id_t), "{ctx}");
            assert!(tiered.is_matchable(id_t), "{ctx}: cold segments stay matchable");
            assert!(!tiered.holds_blocks(id_t), "{ctx}: demotion frees blocks");
            assert_eq!(tiered.cold_tokens(), 48, "{ctx}");
            assert_eq!(tiered.cached_tokens(), 0, "{ctx}");
            assert!(tiered.spill_live_bytes() > 0, "{ctx}");
            assert_eq!(tiered.refault_segment(id_t), Refault::Refaulted, "{ctx}");
            assert!(!tiered.is_cold(id_t), "{ctx}");
            assert_eq!(tiered.free_blocks(), free_before, "{ctx}: refault re-reserves");
            assert_eq!(tiered.spill_live_bytes(), 0, "{ctx}: refault frees the extent");
            assert_eq!(tiered.tokens_of(id_t), &tokens[..], "{ctx}");
            assert_kv_bits_equal(&never.segment(id_n).kv, &tiered.segment(id_t).kv, &ctx);
            assert_queries_equal(&never.segment(id_n).kv, &tiered.segment(id_t).kv, 99, &ctx);
            let s = tiered.tier_stats();
            assert_eq!(s.segments_spilled, 1, "{ctx}");
            assert_eq!(s.segments_refaulted, 1, "{ctx}");
            assert!(s.spill_bytes > 0, "{ctx}");
        }
    }
}

/// A directory-backed spill store round-trips bit-identically and
/// unlinks its backing file when the pool drops.
#[test]
fn dir_backed_spill_roundtrip_and_cleanup() {
    let dir = std::env::temp_dir().join(format!("kv-tier-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let backend = Some(HsrBackend::Brute);
    let src = filled_kv(13, 32, 1, 2, 8, backend);
    let tokens: Vec<u32> = (0..32).collect();
    {
        let tier = TierConfig {
            spill: SpillConfig::Dir(dir.clone()),
            policy: SpillPolicy::SerializeHsr,
        };
        let mut pool = PagePool::with_tier(1 << 10, 16, backend, &tier);
        assert!(pool.spill_enabled(), "dir backing must open");
        let mut never = PagePool::new(1 << 10, 16, backend);
        let id_n = never.create_segment(&tokens, 0, &src, 0).expect("fits");
        let id = pool.create_segment(&tokens, 0, &src, 0).expect("fits");
        assert_eq!(pool.release_segment(id, true, false), Demoted::Spilled);
        assert!(
            std::fs::read_dir(&dir).expect("readable").next().is_some(),
            "spill file must exist while the pool lives"
        );
        assert_eq!(pool.refault_segment(id), Refault::Refaulted);
        assert_kv_bits_equal(&never.segment(id_n).kv, &pool.segment(id).kv, "dir backing");
        assert_queries_equal(&never.segment(id_n).kv, &pool.segment(id).kv, 42, "dir backing");
    }
    assert!(
        std::fs::read_dir(&dir).expect("readable").next().is_none(),
        "dropping the pool must unlink its spill file"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// 32 tenants publish the same 64-token document chunk under 32
/// distinct radix parents: one physical segment, 31 dedup hits, and the
/// logical/physical byte gap equals exactly the bytes dedup saved.
/// Teardown unwinds all 32 owner claims without leaking a block.
#[test]
fn dedup_shares_one_physical_segment_across_tenants() {
    let backend = Some(HsrBackend::BallTree);
    let src = filled_kv(23, 80, 2, 2, 8, backend);
    let shared: Vec<u32> = (0..64).map(|i| (i * 5 + 2) % 256).collect();
    let mut store = PrefixStore::with_tier(
        1 << 12,
        16,
        backend,
        PrefixCacheMode::Min(1),
        &tier_mem(SpillPolicy::RebuildOnRefault),
    );
    let mut child_seg = None;
    for tenant in 0..32u32 {
        let parent_toks: Vec<u32> = (0..16).map(|i| 1000 * (tenant + 1) + i).collect();
        let parent = store
            .publish_segment(None, &parent_toks, 0, &src, 0, 0)
            .expect("parent fits");
        let child = store
            .publish_segment(Some(parent), &shared, 16, &src, 16, 0)
            .expect("child fits or dedups");
        let seg = store.radix.segment_of(child);
        match child_seg {
            None => child_seg = Some(seg),
            Some(first) => assert_eq!(seg, first, "tenant {tenant} must share the segment"),
        }
    }
    let seg = child_seg.unwrap();
    assert_eq!(store.pool.owners_of(seg), 32);
    // 32 unique parents + 1 shared child.
    assert_eq!(store.pool.segment_count(), 33);
    let stats = store.pool.tier_stats();
    assert_eq!(stats.dedup_hits, 31);
    let physical = store.pool.physical_payload_bytes();
    let logical = store.pool.logical_payload_bytes();
    assert!(physical < logical);
    assert_eq!((logical - physical) as u64, stats.dedup_bytes_saved);
    // Teardown: every owner claim unwinds, nothing leaks anywhere.
    store.make_room(usize::MAX);
    assert_eq!(store.pool.segment_count(), 0);
    assert_eq!(store.pool.free_blocks(), store.pool.total_blocks());
    assert_eq!(store.pool.spill_live_bytes(), 0);
    store.pool.debug_assert_all_free();
}

/// `lookup_budgeted` refaults front-to-back within the token budget and
/// truncates the chain at the first node it cannot afford.
#[test]
fn lookup_budget_truncates_refaults() {
    let backend = Some(HsrBackend::BallTree);
    let src = filled_kv(11, 64, 1, 1, 8, backend);
    let tokens: Vec<u32> = (0..64).map(|i| i % 251).collect();
    let mut store = PrefixStore::with_tier(
        1 << 10,
        16,
        backend,
        PrefixCacheMode::Min(1),
        &tier_mem(SpillPolicy::RebuildOnRefault),
    );
    let n0 = store.publish_segment(None, &tokens[..16], 0, &src, 0, 0).expect("fits");
    let n1 = store.publish_segment(Some(n0), &tokens[16..32], 16, &src, 16, 0).expect("fits");
    let n2 = store.publish_segment(Some(n1), &tokens[32..48], 32, &src, 32, 0).expect("fits");
    // Finite want_free keeps the spill path (usize::MAX means teardown):
    // all three nodes demote in place and stay matchable.
    store.make_room(store.pool.total_blocks());
    for n in [n0, n1, n2] {
        assert!(store.pool.is_cold(store.radix.segment_of(n)));
        assert!(store.pool.is_matchable(store.radix.segment_of(n)));
    }
    let mut prompt = tokens[..48].to_vec();
    prompt.push(999);
    // Budget 20 affords the first 16-token node, not the second.
    let (chain, matched) = store.lookup_budgeted(&prompt, 20);
    assert_eq!(chain.len(), 1);
    assert_eq!(matched, 16);
    assert!(store.pool.holds_blocks(store.radix.segment_of(chain[0])));
    assert!(store.pool.is_cold(store.radix.segment_of(n1)), "past-budget node stays cold");
    // Unbudgeted lookup promotes the remainder of the chain.
    let (chain, matched) = store.lookup_budgeted(&prompt, usize::MAX);
    assert_eq!(chain.len(), 3);
    assert_eq!(matched, 48);
    for &n in &chain {
        assert!(store.pool.holds_blocks(store.radix.segment_of(n)));
    }
    assert_eq!(store.pool.tier_stats().segments_refaulted, 3);
    store.make_room(usize::MAX);
    assert_eq!(store.pool.free_blocks(), store.pool.total_blocks());
    assert_eq!(store.pool.spill_live_bytes(), 0);
}

/// Randomized publish/evict/refault churn with a shared dedup'd child:
/// after any interleaving, full teardown leaves the block ledger exact —
/// no double-free, no leaked block, no leaked spill extent.
#[test]
fn churn_publish_evict_refault_no_leak() {
    for (seed, policy) in
        [(101u64, SpillPolicy::RebuildOnRefault), (202u64, SpillPolicy::SerializeHsr)]
    {
        let backend = Some(HsrBackend::BallTree);
        let src = filled_kv(17, 64, 1, 1, 8, backend);
        let variants: Vec<Vec<u32>> =
            (0..6u32).map(|s| (0..32).map(|i| (i * 3 + s * 41 + 5) % 64).collect()).collect();
        let shared: Vec<u32> = (0..16).map(|i| 500 + i).collect();
        // 32 blocks of 16 tokens: tight enough that publishes contend.
        let mut store = PrefixStore::with_tier(
            512,
            16,
            backend,
            PrefixCacheMode::Min(1),
            &tier_mem(policy),
        );
        // Deterministic prologue so every tier path is exercised
        // regardless of how the churn schedule lands: publish, dedup a
        // child under a second parent, demote everything, refault.
        let r0 = store.publish_segment(None, &variants[0], 0, &src, 0, 0).expect("fits");
        store.publish_segment(Some(r0), &shared, 32, &src, 32, 0).expect("fits");
        let r1 = store.publish_segment(None, &variants[1], 0, &src, 0, 0).expect("fits");
        store.publish_segment(Some(r1), &shared, 32, &src, 32, 0).expect("dedups");
        store.make_room(store.pool.total_blocks());
        let mut probe = variants[0].clone();
        probe.push(1000);
        let (chain, _) = store.lookup(&probe);
        assert!(!chain.is_empty(), "demoted prefix must refault on lookup");

        let mut rng = Rng::new(seed);
        for _ in 0..400 {
            match rng.below(4) {
                0 | 1 => {
                    // Publish a variant root (and sometimes a dedup'd
                    // child) unless it is already fully cached.
                    let v = rng.below(variants.len());
                    let mut probe = variants[v].clone();
                    probe.push(1000);
                    let (chain, matched) = store.lookup(&probe);
                    let mut root = if matched >= 32 {
                        Some(chain[0])
                    } else {
                        store.publish_segment(None, &variants[v], 0, &src, 0, 0)
                    };
                    if root.is_none() {
                        store.make_room(4);
                        root = store.publish_segment(None, &variants[v], 0, &src, 0, 0);
                    }
                    if let Some(root) = root {
                        if rng.below(2) == 0 {
                            let _ = store.publish_segment(Some(root), &shared, 32, &src, 32, 0);
                        }
                    }
                }
                2 => {
                    store.make_room(rng.below(16) + 1);
                }
                _ => {
                    let v = rng.below(variants.len());
                    let mut probe = variants[v].clone();
                    probe.push(1001);
                    let (chain, _) = store.lookup(&probe);
                    // Every handed-out node is hot.
                    for &n in &chain {
                        assert!(store.pool.holds_blocks(store.radix.segment_of(n)));
                    }
                }
            }
        }
        let stats = store.pool.tier_stats();
        assert!(stats.dedup_hits >= 1, "policy={policy:?}");
        assert!(stats.segments_spilled >= 1, "policy={policy:?}");
        assert!(stats.segments_refaulted >= 1, "policy={policy:?}");
        store.make_room(usize::MAX);
        assert_eq!(store.pool.segment_count(), 0, "policy={policy:?}");
        assert_eq!(store.pool.free_blocks(), store.pool.total_blocks(), "policy={policy:?}");
        assert_eq!(store.pool.spill_live_bytes(), 0, "policy={policy:?}");
        assert_eq!(store.pool.cold_tokens(), 0, "policy={policy:?}");
        assert_eq!(store.pool.cached_tokens(), 0, "policy={policy:?}");
        store.pool.debug_assert_all_free();
    }
}

/// Engine-level: under a hot cap too small for the working set, a
/// resubmitted prompt refaults its demoted prefix instead of
/// re-prefilling — with outputs bit-identical to the spill-off engine —
/// and full teardown leaks zero blocks across both tiers.
#[test]
fn engine_refaults_instead_of_reprefilling() {
    let model = Arc::new(Model::synthetic(81, 2, 2, 8));
    // Three distinct 96-token prompts overflow a 320-token hot cap once
    // tails are accounted; the fourth submission repeats the first.
    let mut schedule: Vec<Vec<u32>> = (0..3).map(|s| prompt_bytes(s, 96)).collect();
    schedule.push(schedule[0].clone());
    let run = |spill: SpillConfig| {
        let mut eng = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                policy: AttentionPolicy::TopR(RSpec::paper()),
                hsr_backend: Some(HsrBackend::BallTree),
                prefix_cache: PrefixCacheMode::default(),
                cache_capacity_tokens: 320,
                block_tokens: 16,
                spill,
                scheduler: SchedulerConfig { prefill_chunk: 16, ..Default::default() },
                ..Default::default()
            },
        );
        let mut outs = Vec::new();
        for p in &schedule {
            eng.submit(
                p.clone(),
                GenerationParams { max_new_tokens: 6, ..Default::default() },
            );
            eng.run_to_completion();
            let mut done = eng.take_finished();
            assert_eq!(done.len(), 1);
            outs.push(done.pop().unwrap().tokens);
        }
        let stats = eng.prefix_store().pool.tier_stats();
        let leaked = eng.reclaim_and_count_leaks();
        (outs, eng.metrics.clone(), leaked, stats)
    };
    let (off_outs, off_m, off_leak, off_stats) = run(SpillConfig::Off);
    let (mem_outs, mem_m, mem_leak, mem_stats) = run(SpillConfig::Memory);
    assert_eq!(off_outs, mem_outs, "spill must never change outputs");
    assert_eq!(off_outs[0], off_outs[3], "greedy resubmit reproduces");
    assert_eq!(off_leak, 0);
    assert_eq!(mem_leak, 0);
    assert_eq!(off_stats.segments_spilled, 0);
    assert!(mem_stats.segments_spilled >= 1, "hot-cap pressure must demote");
    assert!(mem_stats.segments_refaulted >= 1, "resubmit must refault");
    // The refaulted chain is adopted: materially more prefill skipped
    // than the spill-off engine, whose evicted prefix re-prefilled.
    assert!(
        mem_m.prefill_tokens_skipped >= off_m.prefill_tokens_skipped + 48,
        "refault must skip re-prefill (off {} vs mem {})",
        off_m.prefill_tokens_skipped,
        mem_m.prefill_tokens_skipped
    );
    // Tier counters surfaced on the engine metrics match the pool.
    assert_eq!(mem_m.segments_spilled, mem_stats.segments_spilled);
    assert_eq!(mem_m.segments_refaulted, mem_stats.segments_refaulted);
    assert_eq!(mem_m.spill_bytes, mem_stats.spill_bytes);
    assert_eq!(mem_m.dedup_hits, mem_stats.dedup_hits);
    assert_eq!(mem_m.kv_blocks_leaked, 0);
}

fn tiered_engine_config(seed: u64, spill: SpillConfig, policy: SpillPolicy) -> EngineConfig {
    EngineConfig {
        policy: AttentionPolicy::TopR(RSpec::paper()),
        hsr_backend: Some(HsrBackend::BallTree),
        prefix_cache: PrefixCacheMode::default(),
        cache_capacity_tokens: 320,
        block_tokens: 16,
        spill,
        spill_policy: policy,
        scheduler: SchedulerConfig { prefill_chunk: 16, ..Default::default() },
        seed,
        ..Default::default()
    }
}

/// COW-forking a sequence whose prefix chain was refaulted from the
/// cold tier: the fork shares the promoted chain, both lineages decode
/// bit-identically to a spill-off never-forked reference, and teardown
/// frees every block and spill extent.
#[test]
fn fork_of_refaulted_cold_chain_is_bit_identical_and_leak_free() {
    let model = Arc::new(Model::synthetic(83, 2, 2, 8));
    let hot = prompt_bytes(1, 96);
    // Reference: plain decode, no spill tier, no fork.
    let mut reference_eng = Engine::new(
        Arc::clone(&model),
        tiered_engine_config(0, SpillConfig::Off, SpillPolicy::RebuildOnRefault),
    );
    reference_eng.submit(
        hot.clone(),
        GenerationParams { max_new_tokens: 8, ..Default::default() },
    );
    reference_eng.run_to_completion();
    let reference = reference_eng.take_finished().pop().expect("reference").tokens;

    for policy in [SpillPolicy::RebuildOnRefault, SpillPolicy::SerializeHsr] {
        let ctx = format!("policy={policy:?}");
        let mut eng = Engine::new(
            Arc::clone(&model),
            tiered_engine_config(0, SpillConfig::Memory, policy),
        );
        // Publish the hot chain, then demote it under filler pressure:
        // four distinct 96-token chains overflow the 320-token hot cap.
        for p in [hot.clone(), prompt_bytes(40, 96), prompt_bytes(41, 96), prompt_bytes(42, 96)]
        {
            eng.submit(p, GenerationParams { max_new_tokens: 4, ..Default::default() });
            eng.run_to_completion();
            eng.take_finished();
        }
        assert!(
            eng.prefix_store().pool.tier_stats().segments_spilled >= 1,
            "{ctx}: hot-cap pressure must demote the oldest chain"
        );
        // Re-arrival refaults the cold chain; fork once decode starts.
        let id = eng.submit(
            hot.clone(),
            GenerationParams { max_new_tokens: 8, ..Default::default() },
        );
        let mut guard = 0;
        while eng.generated_len(id).is_some_and(|g| g < 2) {
            eng.step();
            guard += 1;
            assert!(guard < 10_000, "{ctx}: hot prompt never reached decode");
        }
        assert!(
            eng.prefix_store().pool.tier_stats().segments_refaulted >= 1,
            "{ctx}: re-arrival must refault, not re-prefill"
        );
        let child = eng.fork_request(id).expect("a refaulted chain must fork");
        eng.run_to_completion();
        let mut done = eng.take_finished();
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 2, "{ctx}");
        assert_eq!(done[1].id, child, "{ctx}");
        assert_eq!(done[0].tokens, reference, "{ctx}: parent diverged after the fork");
        assert_eq!(done[1].tokens, reference, "{ctx}: fork of a refaulted chain diverged");
        assert_eq!(eng.metrics.sequence_forks, 1, "{ctx}");
        assert_eq!(eng.reclaim_and_count_leaks(), 0, "{ctx}: leaked KV blocks");
        assert_eq!(
            eng.prefix_store().pool.spill_live_bytes(),
            0,
            "{ctx}: teardown must free every spill extent"
        );
        eng.prefix_store().pool.debug_assert_all_free();
    }
}

/// Randomized fork/cancel/preempt churn over a spill-tiered engine with
/// recurring prompts (so cold chains keep refaulting under the churn):
/// every request reaches exactly one terminal response and teardown
/// leaves both tiers exact — zero leaked blocks, zero live spill bytes,
/// zero chain references.
#[test]
fn fork_churn_over_spill_tier_keeps_ledger_exact() {
    let model = Arc::new(Model::synthetic(84, 2, 2, 8));
    for (seed, policy) in
        [(31u64, SpillPolicy::RebuildOnRefault), (32, SpillPolicy::SerializeHsr)]
    {
        let mut eng = Engine::new(
            Arc::clone(&model),
            tiered_engine_config(seed, SpillConfig::Memory, policy),
        );
        // Deterministic prologue: force one demote + refault cycle so
        // the tier paths are exercised however the schedule lands.
        for p in [
            prompt_bytes(1, 96),
            prompt_bytes(40, 96),
            prompt_bytes(41, 96),
            prompt_bytes(42, 96),
            prompt_bytes(1, 96),
        ] {
            eng.submit(p, GenerationParams { max_new_tokens: 3, ..Default::default() });
            eng.run_to_completion();
            eng.take_finished();
        }
        let stats = eng.prefix_store().pool.tier_stats();
        assert!(stats.segments_spilled >= 1, "policy={policy:?}");
        assert!(stats.segments_refaulted >= 1, "policy={policy:?}");

        let mut rng = Rng::new(seed);
        let mut known: Vec<(u64, bool)> = Vec::new();
        let mut expected = 0usize;
        for _ in 0..100 {
            match rng.below(8) {
                0..=2 => {
                    // Recurring prompt seeds: repeats hit (and refault)
                    // the shared chains the churn keeps demoting.
                    let s = [1u32, 2, 3, 40][rng.below(4)];
                    let id = eng.submit(
                        prompt_bytes(s, 64),
                        GenerationParams {
                            max_new_tokens: rng.range(3, 9),
                            ..Default::default()
                        },
                    );
                    known.push((id, false));
                    expected += 1;
                }
                3 => {
                    let s = [1u32, 2][rng.below(2)];
                    let id = eng.submit(
                        prompt_bytes(s, 64),
                        GenerationParams {
                            max_new_tokens: rng.range(3, 9),
                            temperature: 1.0,
                            n: rng.range(2, 4) as u32,
                            ..Default::default()
                        },
                    );
                    known.push((id, true));
                    expected += 1;
                }
                4 if !known.is_empty() => {
                    let (id, grouped) = known[rng.below(known.len())];
                    if let Some(child) = eng.fork_request(id) {
                        if !grouped {
                            known.push((child, false));
                            expected += 1;
                        }
                    }
                }
                5 if !known.is_empty() => {
                    let (id, _) = known[rng.below(known.len())];
                    let _ = eng.cancel(id);
                }
                _ => {
                    for _ in 0..rng.range(1, 7) {
                        eng.step();
                    }
                }
            }
        }
        eng.run_to_completion();
        assert_eq!(
            eng.take_finished().len(),
            expected,
            "policy={policy:?}: every request needs exactly one terminal response"
        );
        assert!(eng.metrics.sequence_forks >= 1, "policy={policy:?}: churn must fork");
        assert_eq!(
            eng.reclaim_and_count_leaks(),
            0,
            "policy={policy:?}: churn leaked KV blocks"
        );
        assert_eq!(
            eng.prefix_store().pool.spill_live_bytes(),
            0,
            "policy={policy:?}: churn leaked spill extents"
        );
        assert_eq!(eng.prefix_store().pool.segment_count(), 0, "policy={policy:?}");
        eng.prefix_store().pool.debug_assert_all_free();
    }
}
