//! Bench/reproduction: **Theorems 4.1 / 4.2** — generation decoding time,
//! HSR-sparse vs naive dense, across KV-cache sizes n.
//!
//! Claim shape: naive is O(mn), Algorithm 1 is O(mn^{4/5}); the sparse
//! curve's fitted exponent should sit well below the dense one's (~1.0)
//! and the speedup should widen with n.

use hsr_attn::attention::relu::relu_attention;
use hsr_attn::attention::softmax::softmax_attention;
use hsr_attn::attention::AttentionKind;
use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::engine::GenerationDecoding;
use hsr_attn::hsr::HsrBackend;
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::{fmt_ns, power_fit};
use hsr_attn::workloads::gaussian::AttentionInstance;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    banner("decode_time", "paper Theorems 4.1/4.2 (decode O(mn^{4/5}) vs O(mn))");
    let bench = Bencher::quick();
    let d = args.usize_or("d", 8);
    let m = args.usize_or("m", 8);
    let ns = args.usize_list_or("ns", &[4_096, 16_384, 65_536, 262_144]);

    for (label, kind) in [
        ("ReLU^2 (Thm 4.1)", AttentionKind::Relu { alpha: 2, bias: 0.0 }),
        ("Softmax top-r (Thm 4.2)", AttentionKind::Softmax),
    ] {
        println!("\n== {label}, d = {d}, m = {m} ==");
        println!(
            "{:>9} | {:>11} {:>11} {:>8} | {:>9}",
            "n", "naive", "hsr", "speedup", "fired"
        );
        let mut xs = Vec::new();
        let mut dense_t = Vec::new();
        let mut sparse_t = Vec::new();
        for &n in &ns {
            let mut rng = Rng::new(n as u64);
            let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
            let bias = inst.params.practical_bias(n) as f32;
            let kind = match kind {
                AttentionKind::Relu { alpha, .. } => AttentionKind::Relu { alpha, bias },
                s => s,
            };
            // Naive dense baseline.
            let naive = bench.run(&format!("naive/n={n}"), || match kind {
                AttentionKind::Relu { alpha, bias } => {
                    black_box(relu_attention(&inst.q, &inst.k, &inst.v, d, alpha, bias));
                }
                AttentionKind::Softmax => {
                    black_box(softmax_attention(&inst.q, &inst.k, &inst.v, d));
                }
            });
            // Algorithm 1 (init outside the timed loop: the decoding
            // scenario amortizes INIT over the whole generation).
            let mut gd =
                GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
            if matches!(kind, AttentionKind::Softmax) {
                gd.top_r = Some((n as f64).powf(0.8) as usize);
                // Softmax needs b s.t. R ⊇ NN(r, q, K): calibrate from the
                // expected top-r quantile (Theorem 4.2's "choose b").
                let target = (n as f64).powf(0.8);
                gd.bias = hsr_attn::attention::threshold::practical_bias_for_target(
                    &inst.params,
                    n,
                    target * 2.0,
                ) as f32;
            }
            let sparse = bench.run(&format!("hsr/n={n}"), || {
                black_box(gd.inference(&inst.q));
            });
            let fired = {
                let mut out = vec![0f32; d];
                let q0: Vec<f32> = inst.query_row(0).to_vec();
                gd.inference_row(&q0, &mut out)
            };
            println!(
                "{:>9} | {:>11} {:>11} {:>7.2}x | {:>9}",
                n,
                fmt_ns(naive.median_ns),
                fmt_ns(sparse.median_ns),
                naive.median_ns / sparse.median_ns,
                fired
            );
            xs.push(n as f64);
            dense_t.push(naive.median_ns);
            sparse_t.push(sparse.median_ns);
        }
        if let (Some((ed, r2d)), Some((es, r2s))) =
            (power_fit(&xs, &dense_t), power_fit(&xs, &sparse_t))
        {
            println!(
                "fitted exponents: naive n^{ed:.2} (r2={r2d:.3})  hsr n^{es:.2} (r2={r2s:.3})"
            );
            println!("paper claim: naive ~n^1.0, Algorithm 1 ~n^0.8");
        }
    }

    // Figure-3 operating point: small fixed r (quality holds down to
    // r ≈ 2^4..2^6) — here sparse decoding wins outright because the
    // selected set, not the identification, dominates the dense cost.
    println!("\n== Softmax fixed top-r = 64 (Figure-3 operating point), d = {d}, m = {m} ==");
    println!("{:>9} | {:>11} {:>11} {:>8}", "n", "naive", "hsr", "speedup");
    for &n in &ns {
        let mut rng = Rng::new(n as u64 + 7);
        let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
        let naive = bench.run(&format!("naive64/n={n}"), || {
            black_box(softmax_attention(&inst.q, &inst.k, &inst.v, d));
        });
        let mut gd = GenerationDecoding::init(
            &inst.k,
            &inst.v,
            d,
            0.0,
            AttentionKind::Softmax,
            HsrBackend::BallTree,
        );
        gd.top_r = Some(64);
        let sparse = bench.run(&format!("hsr64/n={n}"), || {
            black_box(gd.inference(&inst.q));
        });
        println!(
            "{:>9} | {:>11} {:>11} {:>7.2}x",
            n,
            fmt_ns(naive.median_ns),
            fmt_ns(sparse.median_ns),
            naive.median_ns / sparse.median_ns
        );
    }
}
