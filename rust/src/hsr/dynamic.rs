//! Dynamic HSR via the logarithmic method.
//!
//! Theorem B.11 ([AEM92]) includes amortized updates; the decode engine
//! needs them because every generated token appends a key to the cache
//! (Algorithm 1's KV-cache grows during generation). We layer insertions
//! on top of any *static* backend with the classic Bentley–Saxe
//! logarithmic method: maintain buckets of static structures with sizes
//! that double; inserting merges full prefixes of buckets and rebuilds one
//! static structure. A decomposable query (half-space reporting is a union
//! — trivially decomposable) runs over all O(log n) buckets.
//!
//! Amortized insert cost: O((build(n)/n) · log n); with the O(n log n)
//! ball-tree build this is O(log^2 n) per insert.

use super::{build_hsr, HalfSpaceReport, HsrBackend, QueryStats};

/// Base bucket capacity: inserts below this sit in a brute-scanned tail,
/// so tiny caches never pay rebuild costs.
const BASE: usize = 64;

struct Bucket {
    /// Static structure over this bucket's points.
    index: Box<dyn HalfSpaceReport>,
    /// Global ids, parallel to the static structure's local indices.
    ids: Vec<u32>,
    /// Row-major points (kept to allow merging into bigger buckets).
    points: Vec<f32>,
}

/// The serializable skeleton of a [`DynamicHsr`]: the logarithmic
/// bucket decomposition (slot position, ids, points per bucket) plus
/// the brute tail. The static per-bucket indexes are *not* part of the
/// structure — `build_hsr` is deterministic, so rebuilding each bucket
/// from its own points reproduces the index exactly. This is what the
/// tiered KV store's `SpillPolicy::SerializeHsr` writes into a cold
/// record.
pub struct HsrStructure {
    /// One entry per bucket slot; `Some((ids, points))` for occupied
    /// slots, mirroring `DynamicHsr::buckets`.
    pub slots: Vec<Option<(Vec<u32>, Vec<f32>)>>,
    pub tail_ids: Vec<u32>,
    pub tail_points: Vec<f32>,
}

/// A growable half-space reporting structure.
pub struct DynamicHsr {
    backend: HsrBackend,
    d: usize,
    /// buckets[i] holds exactly BASE << i points (or is None).
    buckets: Vec<Option<Bucket>>,
    /// Un-indexed tail, scanned brute-force (size < BASE).
    tail_points: Vec<f32>,
    tail_ids: Vec<u32>,
    len: usize,
    /// Total points rebuilt over the structure's lifetime (cost metric).
    pub rebuilt_points: u64,
    /// Number of static rebuilds performed.
    pub rebuilds: u64,
}

impl DynamicHsr {
    pub fn new(backend: HsrBackend, d: usize) -> DynamicHsr {
        assert!(d > 0);
        DynamicHsr {
            backend,
            d,
            buckets: Vec::new(),
            tail_points: Vec::new(),
            tail_ids: Vec::new(),
            len: 0,
            rebuilt_points: 0,
            rebuilds: 0,
        }
    }

    /// Build from an initial batch (e.g. the prompt's keys), assigning
    /// global ids 0..n. The batch goes into a *single* static structure
    /// parked in the top bucket slot — one build, one tree to query —
    /// instead of replaying n inserts (which would cascade O(log n)
    /// rebuilds and leave the points shredded across O(log n) buckets).
    pub fn from_points(backend: HsrBackend, points: &[f32], d: usize) -> DynamicHsr {
        let mut s = DynamicHsr::new(backend, d);
        let n = points.len() / d;
        if n == 0 {
            return s;
        }
        let index = build_hsr(backend, points, d);
        s.rebuilt_points += n as u64;
        s.rebuilds += 1;
        // Slot chosen so that lower slots absorb ~n further inserts before
        // a carry ever reaches (and merges) this bucket.
        let slot = (n / BASE).max(1).ilog2() as usize + 1;
        while s.buckets.len() <= slot {
            s.buckets.push(None);
        }
        s.buckets[slot] = Some(Bucket {
            index,
            ids: (0..n as u32).collect(),
            points: points.to_vec(),
        });
        s.len = n;
        s
    }

    /// Insert one point; its global id is its insertion order.
    pub fn insert(&mut self, point: &[f32]) -> u32 {
        assert_eq!(point.len(), self.d);
        let id = self.len as u32;
        self.len += 1;
        self.tail_points.extend_from_slice(point);
        self.tail_ids.push(id);
        if self.tail_ids.len() >= BASE {
            self.carry();
        }
        id
    }

    /// Merge the tail plus every full prefix of buckets into the first
    /// free slot (binary carry).
    fn carry(&mut self) {
        let mut points = std::mem::take(&mut self.tail_points);
        let mut ids = std::mem::take(&mut self.tail_ids);
        let mut slot = 0;
        loop {
            if slot == self.buckets.len() {
                self.buckets.push(None);
            }
            match self.buckets[slot].take() {
                None => {
                    let index = build_hsr(self.backend, &points, self.d);
                    self.rebuilt_points += ids.len() as u64;
                    self.rebuilds += 1;
                    self.buckets[slot] = Some(Bucket { index, ids, points });
                    return;
                }
                Some(b) => {
                    points.extend_from_slice(&b.points);
                    ids.extend_from_slice(&b.ids);
                    slot += 1;
                }
            }
        }
    }

    /// Number of active buckets (for tests/metrics).
    pub fn bucket_count(&self) -> usize {
        self.buckets.iter().filter(|b| b.is_some()).count()
    }

    /// Snapshot the bucket decomposition (see [`HsrStructure`]).
    pub fn structure(&self) -> HsrStructure {
        HsrStructure {
            slots: self
                .buckets
                .iter()
                .map(|b| b.as_ref().map(|b| (b.ids.clone(), b.points.clone())))
                .collect(),
            tail_ids: self.tail_ids.clone(),
            tail_points: self.tail_points.clone(),
        }
    }

    /// Reconstruct a structure snapshotted by [`DynamicHsr::structure`]:
    /// every bucket keeps its slot position and contents, with its
    /// static index deterministically rebuilt from its own points.
    /// Queries against the result are bit-identical to the original —
    /// same buckets, same in-bucket point order, same traversals.
    pub fn from_structure(backend: HsrBackend, d: usize, s: &HsrStructure) -> DynamicHsr {
        assert!(d > 0);
        let mut len = s.tail_ids.len();
        let mut rebuilt_points = 0u64;
        let mut rebuilds = 0u64;
        let buckets = s
            .slots
            .iter()
            .map(|slot| {
                slot.as_ref().map(|(ids, points)| {
                    debug_assert_eq!(points.len(), ids.len() * d);
                    len += ids.len();
                    rebuilt_points += ids.len() as u64;
                    rebuilds += 1;
                    Bucket {
                        index: build_hsr(backend, points, d),
                        ids: ids.clone(),
                        points: points.clone(),
                    }
                })
            })
            .collect();
        DynamicHsr {
            backend,
            d,
            buckets,
            tail_points: s.tail_points.clone(),
            tail_ids: s.tail_ids.clone(),
            len,
            rebuilt_points,
            rebuilds,
        }
    }
}

impl HalfSpaceReport for DynamicHsr {
    fn len(&self) -> usize {
        self.len
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        assert_eq!(a.len(), self.d);
        // Tail: brute scan.
        for (slot, &id) in self.tail_ids.iter().enumerate() {
            stats.points_scanned += 1;
            let p = &self.tail_points[slot * self.d..(slot + 1) * self.d];
            if super::dot(p, a) >= b {
                out.push(id);
                stats.reported += 1;
            }
        }
        // Buckets: query each static structure straight into `out`, then
        // remap the freshly appended local ids → global ids in place (no
        // intermediate buffer — this path runs once per decoded token).
        for bucket in self.buckets.iter().flatten() {
            let start = out.len();
            bucket.index.query_into(a, b, out, stats);
            for x in &mut out[start..] {
                *x = bucket.ids[*x as usize];
            }
        }
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        assert_eq!(a.len(), self.d);
        // Tail: brute scan, score from the membership dot.
        for (slot, &id) in self.tail_ids.iter().enumerate() {
            stats.points_scanned += 1;
            let p = &self.tail_points[slot * self.d..(slot + 1) * self.d];
            let s = super::dot(p, a);
            if s >= b {
                out.push(id);
                scores.push(s);
                stats.reported += 1;
            }
        }
        // Buckets: scores need no remapping, only the ids do.
        for bucket in self.buckets.iter().flatten() {
            let start = out.len();
            bucket.index.query_scored_into(a, b, out, scores, stats);
            for x in &mut out[start..] {
                *x = bucket.ids[*x as usize];
            }
        }
    }

    /// Native shared traversal: the decomposable query runs the whole
    /// block against each static bucket **once** (the bucket's own
    /// shared-traversal override does the node amortization), with the
    /// brute tail scanned per query. Per-query output order matches
    /// [`HalfSpaceReport::query_scored_into`]: tail first, then buckets
    /// in slot order.
    fn query_many_scored_into(
        &self,
        queries: &[f32],
        bs: &[f32],
        outs: &mut [Vec<u32>],
        scores: &mut [Vec<f32>],
        stats: &mut QueryStats,
    ) {
        let d = self.d;
        let q = bs.len();
        assert_eq!(queries.len(), q * d);
        assert_eq!(outs.len(), q);
        assert_eq!(scores.len(), q);
        // Tail: per-(query, point) brute scan, scoring the membership dot.
        for i in 0..q {
            let a = &queries[i * d..(i + 1) * d];
            for (slot, &id) in self.tail_ids.iter().enumerate() {
                stats.points_scanned += 1;
                let p = &self.tail_points[slot * d..(slot + 1) * d];
                let s = super::dot(p, a);
                if s >= bs[i] {
                    outs[i].push(id);
                    scores[i].push(s);
                    stats.reported += 1;
                }
            }
        }
        // Buckets: one shared block traversal each, then remap the
        // freshly appended local ids → global ids per query. Per-query
        // append positions live in a stack buffer so the hot path stays
        // allocation-free; blocks wider than it fall back to per-query
        // bucket queries (identical results and per-point counters).
        const MAX_BLOCK: usize = 64;
        for bucket in self.buckets.iter().flatten() {
            if q > MAX_BLOCK {
                for i in 0..q {
                    let start = outs[i].len();
                    bucket.index.query_scored_into(
                        &queries[i * d..(i + 1) * d],
                        bs[i],
                        &mut outs[i],
                        &mut scores[i],
                        stats,
                    );
                    for x in &mut outs[i][start..] {
                        *x = bucket.ids[*x as usize];
                    }
                }
                continue;
            }
            let mut starts = [0usize; MAX_BLOCK];
            for (s, o) in starts.iter_mut().zip(outs.iter()) {
                *s = o.len();
            }
            bucket.index.query_many_scored_into(queries, bs, outs, scores, stats);
            for (i, o) in outs.iter_mut().enumerate() {
                for x in &mut o[starts[i]..] {
                    *x = bucket.ids[*x as usize];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::{reference_query, HsrBackend};
    use crate::util::rng::Rng;

    fn check_against_reference(backend: HsrBackend, d: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let mut dynamic = DynamicHsr::new(backend, d);
        let mut all_points: Vec<f32> = Vec::new();
        for step in 0..700 {
            let p = rng.gaussian_vec_f32(d, 1.0);
            let id = dynamic.insert(&p);
            assert_eq!(id as usize, step);
            all_points.extend_from_slice(&p);
            if step % 97 == 0 || step == 699 {
                let a = rng.gaussian_vec_f32(d, 1.0);
                let b = rng.normal(0.0, 1.0) as f32;
                assert_eq!(
                    dynamic.query(&a, b),
                    reference_query(&all_points, d, &a, b),
                    "step={step}"
                );
            }
        }
        assert_eq!(dynamic.len(), 700);
    }

    #[test]
    fn balltree_backend_incremental() {
        check_against_reference(HsrBackend::BallTree, 8, 31);
    }

    #[test]
    fn brute_backend_incremental() {
        check_against_reference(HsrBackend::Brute, 3, 32);
    }

    #[test]
    fn layers2d_backend_incremental() {
        check_against_reference(HsrBackend::Layers2d, 2, 33);
    }

    #[test]
    fn bucket_structure_is_binary() {
        let mut rng = Rng::new(1);
        let mut s = DynamicHsr::new(HsrBackend::Brute, 2);
        for _ in 0..(BASE * 5) {
            let p = rng.gaussian_vec_f32(2, 1.0);
            s.insert(&p);
        }
        // 5 * BASE points = binary 101 → exactly two full buckets.
        assert_eq!(s.bucket_count(), 2);
        assert_eq!(s.len(), BASE * 5);
    }

    #[test]
    fn amortized_rebuild_cost_is_logarithmic() {
        let mut rng = Rng::new(2);
        let n = 16 * BASE * 8;
        let mut s = DynamicHsr::new(HsrBackend::BallTree, 4);
        for _ in 0..n {
            let p = rng.gaussian_vec_f32(4, 1.0);
            s.insert(&p);
        }
        // Total rebuilt points is O(n log(n/BASE)); assert a generous bound.
        let log_factor = ((n / BASE) as f64).log2();
        assert!(
            (s.rebuilt_points as f64) < 2.0 * n as f64 * log_factor,
            "rebuilt {} for n={n}",
            s.rebuilt_points
        );
    }

    #[test]
    fn from_points_matches_batch() {
        let mut rng = Rng::new(3);
        let d = 5;
        let pts = rng.gaussian_vec_f32(333 * d, 1.0);
        let s = DynamicHsr::from_points(HsrBackend::BallTree, &pts, d);
        let a = rng.gaussian_vec_f32(d, 1.0);
        assert_eq!(s.query(&a, 0.3), reference_query(&pts, d, &a, 0.3));
    }

    #[test]
    fn empty_query() {
        let s = DynamicHsr::new(HsrBackend::BallTree, 4);
        assert!(s.query(&[1.0, 0.0, 0.0, 0.0], 0.0).is_empty());
    }

    #[test]
    fn structure_roundtrip_is_bit_faithful() {
        use crate::hsr::QueryStats;
        let mut rng = Rng::new(9);
        let d = 6;
        // Insertion-grown: multiple buckets at specific slots plus a
        // partial tail — the case from_points cannot reproduce.
        let mut orig = DynamicHsr::new(HsrBackend::BallTree, d);
        for _ in 0..(BASE * 5 + 17) {
            let p = rng.gaussian_vec_f32(d, 1.0);
            orig.insert(&p);
        }
        let rebuilt = DynamicHsr::from_structure(HsrBackend::BallTree, d, &orig.structure());
        assert_eq!(rebuilt.len(), orig.len());
        assert_eq!(rebuilt.bucket_count(), orig.bucket_count());
        assert_eq!(rebuilt.tail_ids, orig.tail_ids);
        for _ in 0..8 {
            let a = rng.gaussian_vec_f32(d, 1.0);
            let b = rng.normal(0.0, 1.0) as f32;
            let (mut o1, mut s1) = (Vec::new(), Vec::new());
            let (mut o2, mut s2) = (Vec::new(), Vec::new());
            let mut st = QueryStats::default();
            orig.query_scored_into(&a, b, &mut o1, &mut s1, &mut st);
            rebuilt.query_scored_into(&a, b, &mut o2, &mut s2, &mut st);
            // Not just the same set: the same order and the same score
            // bit patterns, because the traversal is identical.
            assert_eq!(o1, o2);
            assert_eq!(
                s1.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                s2.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
        }
    }
}
