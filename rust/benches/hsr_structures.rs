//! Bench/reproduction: **Corollary 3.1** — HSR init/query scaling across
//! backends, plus the dynamic-update amortization of Theorem B.11, plus
//! the kernel-layer before/after microbenches (scalar/serial baseline vs
//! SIMD/parallel) emitted machine-readably to `BENCH_kernels.json`.
//!
//! Expected shapes:
//!  * init: brute O(n), ball-tree / layers2d O(n log n)-ish.
//!  * query: output-sensitive for ball-tree (low d) and layers2d (d = 2),
//!    degrading toward linear as d grows (the AEM n^{1-1/⌊d/2⌋} story).
//!  * dynamic inserts: amortized ~log² n.
//!  * kernels: ≥2x on dense scoring (n=8192, d=64), ≥1.5x end-to-end on
//!    `PromptPrefilling::inference` (m=512, n=8192, d=16, balltree).
//!
//! `--kernels-only` skips the HSR-structure sections (used by
//! scripts/verify.sh for the perf smoke run).

use hsr_attn::attention::AttentionKind;
use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::engine::PromptPrefilling;
use hsr_attn::hsr::dynamic::DynamicHsr;
use hsr_attn::hsr::{build_hsr, gaussian_points, HsrBackend, QueryStats};
use hsr_attn::kernel::simd;
use hsr_attn::util::cli::Args;
use hsr_attn::util::json::Json;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::{fmt_ns, power_fit};
use hsr_attn::workloads::gaussian::AttentionInstance;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    banner(
        "hsr_structures",
        "paper Corollary 3.1 / Theorem B.11 (HSR costs) + kernel layer",
    );
    let bench = Bencher::quick();
    if !args.flag("kernels-only") {
        structures_bench(&bench);
        dynamic_bench(&bench);
    }
    kernel_bench(&bench);
}

fn structures_bench(bench: &Bencher) {
    let ns = [4_096usize, 16_384, 65_536];

    // ---- init + query across backends ----
    for d in [2usize, 8, 16] {
        println!("\n== d = {d} ==");
        println!(
            "{:>9} {:>10} | {:>11} {:>11} | {:>10} {:>10}",
            "backend", "n", "init", "query", "scanned", "reported"
        );
        let backends: Vec<HsrBackend> = if d == 2 {
            vec![HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Layers2d]
        } else {
            vec![HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected]
        };
        for backend in backends {
            let mut q_times = Vec::new();
            let mut sizes = Vec::new();
            for &n in &ns {
                let mut rng = Rng::new(n as u64);
                let pts = gaussian_points(&mut rng, n, d, 1.0);
                let init = bench.run(&format!("{}/init/n={n}", backend.name()), || {
                    black_box(build_hsr(backend, &pts, d));
                });
                let index = build_hsr(backend, &pts, d);
                // Threshold reporting ~n^{4/5} entries (Lemma 6.1 regime).
                let q = rng.gaussian_vec_f32(d, 1.0);
                let qn = hsr_attn::hsr::norm(&q) as f64;
                let b = (qn / (d as f64).sqrt() * (0.4 * (n as f64).ln()).sqrt()
                    * (d as f64).sqrt()) as f32;
                let mut out = Vec::new();
                let mut stats = QueryStats::default();
                index.query_into(&q, b, &mut out, &mut stats);
                let query = bench.run(&format!("{}/query/n={n}", backend.name()), || {
                    let mut o = Vec::new();
                    let mut s = QueryStats::default();
                    index.query_into(&q, b, &mut o, &mut s);
                    black_box(o.len());
                });
                println!(
                    "{:>9} {:>10} | {:>11} {:>11} | {:>10} {:>10}",
                    backend.name(),
                    n,
                    fmt_ns(init.median_ns),
                    fmt_ns(query.median_ns),
                    stats.points_scanned,
                    stats.reported
                );
                q_times.push(query.median_ns);
                sizes.push(n as f64);
            }
            if let Some((e, r2)) = power_fit(&sizes, &q_times) {
                println!(
                    "{:>9}   query-time exponent fit: n^{e:.2} (r2={r2:.3})",
                    backend.name()
                );
            }
        }
    }
}

fn dynamic_bench(bench: &Bencher) {
    let ns = [4_096usize, 16_384, 65_536];
    // ---- dynamic updates (logarithmic method) ----
    println!("\n== dynamic inserts (Theorem B.11 amortized updates), d = 8 ==");
    println!("{:>9} | {:>12} {:>14} {:>10}", "n", "total", "per-insert", "rebuilds");
    for &n in &ns {
        let mut rng = Rng::new(n as u64 + 1);
        let points: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec_f32(8, 1.0)).collect();
        let r = bench.run(&format!("dynamic_insert/n={n}"), || {
            let mut dynamic = DynamicHsr::new(HsrBackend::BallTree, 8);
            for p in &points {
                dynamic.insert(p);
            }
            black_box(&dynamic);
        });
        let mut dynamic = DynamicHsr::new(HsrBackend::BallTree, 8);
        for p in &points {
            dynamic.insert(p);
        }
        println!(
            "{:>9} | {:>12} {:>14} {:>10}",
            n,
            fmt_ns(r.median_ns),
            fmt_ns(r.median_ns / n as f64),
            dynamic.rebuilds
        );
    }
    println!("\nexpected: per-insert cost grows ~log^2 n, not with n.");
}

/// One before/after kernel case for the JSON report.
struct KernelCase {
    name: &'static str,
    baseline_ns_per_row: f64,
    optimized_ns_per_row: f64,
}

impl KernelCase {
    fn speedup(&self) -> f64 {
        self.baseline_ns_per_row / self.optimized_ns_per_row.max(1e-9)
    }
}

/// The softmax row exactly as the pre-kernel crate computed it: scalar
/// unrolled dots, two-pass softmax that recomputes exp, scalar axpy.
fn softmax_row_baseline(
    q: &[f32],
    keys: &[f32],
    values: &[f32],
    d: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let n = keys.len() / d;
    scores.resize(n, 0.0);
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for (j, s) in scores.iter_mut().enumerate() {
        *s = simd::dot_scalar(q, &keys[j * d..(j + 1) * d]) * inv_sqrt_d;
    }
    out.fill(0.0);
    let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut denom = 0f32;
    for &s in scores.iter() {
        denom += (s - max).exp();
    }
    if denom == 0.0 || !denom.is_finite() {
        return;
    }
    let inv = 1.0 / denom;
    for (j, &s) in scores.iter().enumerate() {
        let w = (s - max).exp() * inv;
        for (o, &v) in out.iter_mut().zip(&values[j * d..(j + 1) * d]) {
            *o += w * v;
        }
    }
}

fn kernel_bench(bench: &Bencher) {
    println!("\n== kernel layer: scalar/serial baseline vs SIMD/parallel ==");
    println!("dispatch: {}", simd::dispatch_name());
    let mut cases: Vec<KernelCase> = Vec::new();

    // --- dot, n=8192 rows of d=64 ---
    {
        let (n, d) = (8_192usize, 64usize);
        let mut rng = Rng::new(1);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let keys = rng.gaussian_vec_f32(n * d, 1.0);
        let base = bench.run("dot/scalar", || {
            let mut acc = 0f32;
            for j in 0..n {
                acc += simd::dot_scalar(&q, &keys[j * d..(j + 1) * d]);
            }
            black_box(acc);
        });
        let opt = bench.run("dot/simd", || {
            let mut acc = 0f32;
            for j in 0..n {
                acc += simd::dot(&q, &keys[j * d..(j + 1) * d]);
            }
            black_box(acc);
        });
        cases.push(KernelCase {
            name: "dot_n8192_d64",
            baseline_ns_per_row: base.median_ns / n as f64,
            optimized_ns_per_row: opt.median_ns / n as f64,
        });
    }

    // --- dense scores_into, n=8192, d=64 (acceptance: ≥2x) ---
    {
        let (n, d) = (8_192usize, 64usize);
        let mut rng = Rng::new(2);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let keys = rng.gaussian_vec_f32(n * d, 1.0);
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = vec![0f32; n];
        let base = bench.run("scores_into/scalar", || {
            simd::scaled_dots_into_scalar(&q, &keys, d, scale, &mut out);
            black_box(out[n - 1]);
        });
        let opt = bench.run("scores_into/simd", || {
            simd::scaled_dots_into(&q, &keys, d, scale, &mut out);
            black_box(out[n - 1]);
        });
        cases.push(KernelCase {
            name: "scores_into_n8192_d64",
            baseline_ns_per_row: base.median_ns / n as f64,
            optimized_ns_per_row: opt.median_ns / n as f64,
        });
    }

    // --- full softmax attention row, n=4096, d=64 ---
    {
        let (n, d) = (4_096usize, 64usize);
        let mut rng = Rng::new(3);
        let q = rng.gaussian_vec_f32(d, 1.0);
        let keys = rng.gaussian_vec_f32(n * d, 1.0);
        let values = rng.gaussian_vec_f32(n * d, 1.0);
        let mut scores = Vec::new();
        let mut out = vec![0f32; d];
        let base = bench.run("softmax_row/baseline", || {
            softmax_row_baseline(&q, &keys, &values, d, &mut scores, &mut out);
            black_box(out[0]);
        });
        let opt = bench.run("softmax_row/kernel", || {
            hsr_attn::attention::softmax::softmax_attention_row(
                &q, &keys, &values, d, &mut scores, &mut out,
            );
            black_box(out[0]);
        });
        cases.push(KernelCase {
            name: "softmax_row_n4096_d64",
            baseline_ns_per_row: base.median_ns,
            optimized_ns_per_row: opt.median_ns,
        });
    }

    // --- end-to-end prefill, m=512, n=8192, d=16, balltree (≥1.5x) ---
    {
        let (m, n, d) = (512usize, 8_192usize, 16usize);
        let mut rng = Rng::new(4);
        let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
        let bias = inst.params.practical_bias(n) as f32;
        let mut pp = PromptPrefilling::new(
            AttentionKind::Relu { alpha: 2, bias },
            HsrBackend::BallTree,
        );
        pp.bias_override = Some(bias);
        // Baseline: the pre-PR configuration — scalar kernels, one thread.
        simd::force_scalar(true);
        pp.threads = 1;
        let base = bench.run("prefill/scalar+serial", || {
            black_box(pp.inference(&inst.q, &inst.k, &inst.v, n, m, d).fired.len());
        });
        // Optimized: runtime-dispatched SIMD + parallel row shards.
        simd::force_scalar(false);
        pp.threads = 0;
        let opt = bench.run("prefill/simd+parallel", || {
            black_box(pp.inference(&inst.q, &inst.k, &inst.v, n, m, d).fired.len());
        });
        cases.push(KernelCase {
            name: "prefill_m512_n8192_d16_balltree",
            baseline_ns_per_row: base.median_ns / m as f64,
            optimized_ns_per_row: opt.median_ns / m as f64,
        });
    }

    println!(
        "{:>34} | {:>14} {:>14} {:>8}",
        "kernel", "before ns/row", "after ns/row", "speedup"
    );
    for c in &cases {
        println!(
            "{:>34} | {:>14.1} {:>14.1} {:>7.2}x",
            c.name,
            c.baseline_ns_per_row,
            c.optimized_ns_per_row,
            c.speedup()
        );
    }

    // Machine-readable report at the repo root.
    let mut root = Json::obj();
    root.set("dispatch", simd::dispatch_name().into());
    root.set(
        "threads",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).into(),
    );
    let items: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("name", c.name.into())
                .set("baseline_ns_per_row", c.baseline_ns_per_row.into())
                .set("optimized_ns_per_row", c.optimized_ns_per_row.into())
                .set("speedup", c.speedup().into());
            o
        })
        .collect();
    root.set("kernels", Json::Arr(items));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
