//! PJRT artifact runtime: load `artifacts/*.hlo.txt` (produced by
//! `python/compile/aot.py`), compile on the PJRT CPU client, execute with
//! concrete buffers. Python is never on this path — the HLO text is the
//! only interchange (see /opt/xla-example/README.md for why text, not
//! serialized protos).
//!
//! The runtime is used for (a) the dense decode/prefill *baseline*
//! executables, (b) executing the L1 Pallas masked-attention kernels from
//! rust, and (c) cross-validating the native rust forward against the JAX
//! lowering (golden tests in `rust/tests/`).
//!
//! The `xla` bindings crate is not part of the hermetic dependency set,
//! so the real client is gated behind the `pjrt` cargo feature. Without
//! it this module compiles to a stub whose constructor returns an error —
//! callers (CLI `info`, runtime tests) already handle the
//! artifacts-unavailable path gracefully.

pub mod artifact;

use anyhow::Result;
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use anyhow::Context;

pub use artifact::{ArtifactManifest, ArtifactSpec, IoSpec};

/// A compiled HLO executable plus its I/O description.
pub struct Executable {
    pub name: String,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT CPU runtime: one client, many compiled artifacts.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    pub manifest: ArtifactManifest,
}

/// An input/output buffer for executable calls.
#[derive(Debug, Clone, PartialEq)]
pub enum Buffer {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Buffer {
    pub fn scalar_i32(v: i32) -> Buffer {
        Buffer::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Buffer {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Buffer::F32(data, shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Buffer {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Buffer::I32(data, shape)
    }

    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Buffer::F32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Buffer::I32(data, shape) => {
                let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        })
    }

    /// Extract f32 payload (errors on i32 buffers).
    pub fn expect_f32(&self) -> Result<&[f32]> {
        match self {
            Buffer::F32(d, _) => Ok(d),
            _ => anyhow::bail!("buffer is not f32"),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client and read the artifact manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = ArtifactManifest::load(&artifacts_dir.join("manifest.json"))?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf(), manifest })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by manifest key (e.g.
    /// "decode_step_small"). Compilation is cached per call site — hold
    /// the returned [`Executable`] for the serving lifetime.
    pub fn load(&self, key: &str) -> Result<Executable> {
        let spec = self
            .manifest
            .hlo
            .get(key)
            .with_context(|| format!("artifact '{key}' not in manifest"))?;
        let path = self.artifacts_dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{key}'"))?;
        Ok(Executable { name: key.to_string(), exe })
    }

    /// Execute with the given inputs; returns the flattened output tuple
    /// as f32 buffers (all exported artifacts produce f32 outputs).
    pub fn execute(&self, exe: &Executable, inputs: &[Buffer]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|b| b.to_literal())
            .collect::<Result<_>>()?;
        let mut result = exe.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let parts = result.decompose_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub constructor: the manifest is still parsed (so `info`-style
    /// callers see the artifact inventory in the error path), but no PJRT
    /// client exists without the `pjrt` feature.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let _manifest = ArtifactManifest::load(&artifacts_dir.join("manifest.json"))?;
        anyhow::bail!(
            "PJRT runtime unavailable: hsr-attn was built without the `pjrt` \
             feature (the xla bindings are not in the hermetic dependency set)"
        )
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "unavailable (built without pjrt)".to_string()
    }

    /// Stub: always errors.
    pub fn load(&self, key: &str) -> Result<Executable> {
        anyhow::bail!("cannot load artifact '{key}': built without the `pjrt` feature")
    }

    /// Stub: always errors.
    pub fn execute(&self, _exe: &Executable, _inputs: &[Buffer]) -> Result<Vec<Vec<f32>>> {
        anyhow::bail!("cannot execute: built without the `pjrt` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_shape_checks() {
        let b = Buffer::f32(vec![1.0, 2.0], vec![2]);
        assert_eq!(b.expect_f32().unwrap(), &[1.0, 2.0]);
        let s = Buffer::scalar_i32(42);
        assert!(s.expect_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn buffer_shape_mismatch_panics() {
        let _ = Buffer::f32(vec![1.0; 3], vec![2, 2]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        // Missing manifest errors first; either way it must not panic.
        match Runtime::new(std::path::Path::new("/nonexistent")) {
            Err(e) => {
                let _ = e.to_string();
            }
            Ok(_) => panic!("stub Runtime::new must error"),
        }
    }
}
