//! The native transformer mirror of `python/compile/model.py`.
//!
//! The serving hot path needs *data-dependent* sparse attention — the HSR
//! report set differs per query — which a fixed-shape XLA executable
//! cannot express without padding to the worst case. So the engine runs
//! the model natively in rust (this module), with weights trained and
//! exported by the Python build step, while the [`crate::runtime`] path
//! executes the AOT-compiled dense artifacts for baseline comparison and
//! cross-validation. Golden-vector tests assert the two agree.
//!
//! Architecture contract (must match model.py exactly): byte-level
//! embedding → L × [RMSNorm → RoPE MHA → residual → RMSNorm → SwiGLU →
//! residual] → RMSNorm → untied output projection. No biases, fp32.

pub mod kv;
pub mod tokenizer;
pub mod transformer;

use crate::util::tensor_io::TensorBundle;
use anyhow::{Context, Result};
use std::path::Path;

/// Model hyperparameters (mirrors `ModelConfig` in model.py; loaded from
/// the weight bundle's `config` metadata).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    pub vocab: usize,
    pub rope_theta: f64,
    pub rms_eps: f32,
}

impl ModelConfig {
    fn from_meta(meta: &crate::util::json::Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: meta.req_str("name")?.to_string(),
            d_model: meta.req_usize("d_model")?,
            n_layers: meta.req_usize("n_layers")?,
            n_heads: meta.req_usize("n_heads")?,
            d_head: meta.req_usize("d_head")?,
            d_ffn: meta.req_usize("d_ffn")?,
            vocab: meta.req_usize("vocab")?,
            rope_theta: meta.req_f64("rope_theta")?,
            rms_eps: meta.req_f64("rms_eps")? as f32,
        })
    }
}

/// A loaded model: config + weights.
pub struct Model {
    pub cfg: ModelConfig,
    pub weights: TensorBundle,
}

impl Model {
    /// Load from `artifacts/model_<name>` (the `.json`/`.bin` pair).
    pub fn load(stem: &Path) -> Result<Model> {
        let weights = TensorBundle::load(stem)
            .with_context(|| format!("loading model bundle {}", stem.display()))?;
        let meta = weights
            .meta
            .get("config")
            .context("model bundle missing 'config' metadata")?;
        let cfg = ModelConfig::from_meta(meta)?;
        // Validate the tensors we depend on exist with the right shapes.
        let emb = weights.get("tok_emb")?;
        anyhow::ensure!(
            emb.shape == vec![cfg.vocab, cfg.d_model],
            "tok_emb shape {:?} != [{}, {}]",
            emb.shape,
            cfg.vocab,
            cfg.d_model
        );
        for i in 0..cfg.n_layers {
            for t in ["attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w1", "w3", "w2"] {
                weights
                    .get(&format!("{t}.{i}"))
                    .with_context(|| format!("layer {i} missing {t}"))?;
            }
        }
        weights.get("final_norm")?;
        weights.get("w_out")?;
        Ok(Model { cfg, weights })
    }

    /// Convenience: load `model_<name>` from an artifacts directory.
    pub fn load_named(artifacts_dir: &Path, name: &str) -> Result<Model> {
        Model::load(&artifacts_dir.join(format!("model_{name}")))
    }

    /// Deterministic random-weight model for tests and benches that must
    /// run without exported artifacts (same seed → identical weights).
    /// `d_head` defaults small in callers on purpose: at `d_head <= 8`
    /// every SIMD dot reduction in the crate is layout-independent, so
    /// shared-prefix vs unshared decode can be asserted **bit**-equal.
    pub fn synthetic(
        seed: u64,
        n_layers: usize,
        n_heads: usize,
        d_head: usize,
    ) -> Model {
        use crate::util::tensor_io::{Tensor, TensorBundle};
        let mut rng = crate::util::rng::Rng::new(seed);
        let d_model = n_heads * d_head;
        let cfg = ModelConfig {
            name: format!("synthetic-{seed}"),
            d_model,
            n_layers,
            n_heads,
            d_head,
            d_ffn: 4 * d_model,
            vocab: 256, // byte-level: works with ByteTokenizer prompts
            rope_theta: 10000.0,
            rms_eps: 1e-5,
        };
        let mut weights = TensorBundle::default();
        let mat = |rng: &mut crate::util::rng::Rng, r: usize, c: usize| {
            Tensor::new(vec![r, c], rng.gaussian_vec_f32(r * c, 0.4))
        };
        weights.insert("tok_emb", mat(&mut rng, cfg.vocab, cfg.d_model));
        weights.insert("w_out", mat(&mut rng, cfg.d_model, cfg.vocab));
        weights.insert(
            "final_norm",
            Tensor::new(vec![cfg.d_model], vec![1.0; cfg.d_model]),
        );
        for l in 0..cfg.n_layers {
            for name in ["wq", "wk", "wv", "wo"] {
                weights.insert(&format!("{name}.{l}"), mat(&mut rng, cfg.d_model, cfg.d_model));
            }
            weights.insert(&format!("w1.{l}"), mat(&mut rng, cfg.d_model, cfg.d_ffn));
            weights.insert(&format!("w3.{l}"), mat(&mut rng, cfg.d_model, cfg.d_ffn));
            weights.insert(&format!("w2.{l}"), mat(&mut rng, cfg.d_ffn, cfg.d_model));
            for name in ["attn_norm", "mlp_norm"] {
                weights.insert(
                    &format!("{name}.{l}"),
                    Tensor::new(vec![cfg.d_model], vec![1.0; cfg.d_model]),
                );
            }
        }
        Model { cfg, weights }
    }

    pub fn tensor(&self, name: &str) -> &crate::util::tensor_io::Tensor {
        self.weights.get(name).expect("validated at load")
    }

    pub fn layer_tensor(&self, name: &str, layer: usize) -> &crate::util::tensor_io::Tensor {
        self.weights
            .get(&format!("{name}.{layer}"))
            .expect("validated at load")
    }
}
