//! Protocol-hardening property tests (deterministic [`Rng`]-driven, no
//! external property-test crate): arbitrary byte soup, truncations, and
//! mutations of valid lines must never panic `parse_request` or
//! `parse_frame`, and well-formed requests and streaming frames must
//! survive a render → parse round trip.

use hsr_attn::engine::{Choice, FinishReason, Response};
use hsr_attn::model::tokenizer::ByteTokenizer;
use hsr_attn::server::{
    parse_admin, parse_frame, parse_request, parse_stats_response,
    render_cancelled_frame_sibling, render_choice_done_frame, render_done_frame,
    render_keepalive, render_request, render_stats_request, render_stats_response,
    render_stats_text_response, render_stream_error_sibling, render_token_frame,
    AdminCmd, StatsFormat, StatsReply, StreamFrame, WireRequest,
};
use hsr_attn::util::json::Json;
use hsr_attn::util::rng::Rng;

/// Characters a generated prompt draws from: ASCII, JSON-significant
/// escapes, and multibyte UTF-8 (exercises the escaper and the
/// char-boundary handling in the truncation test).
const PROMPT_CHARS: &[char] = &[
    'a', 'b', 'z', ' ', '0', '9', '"', '\\', '/', '\n', '\r', '\t', '{', '}',
    '[', ']', ':', ',', 'é', 'π', '✓',
];

fn random_request(rng: &mut Rng) -> WireRequest {
    let prompt: String = (0..rng.range(1, 48))
        .map(|_| PROMPT_CHARS[rng.below(PROMPT_CHARS.len())])
        .collect();
    WireRequest {
        prompt,
        // Stay inside parse_request's clamp range so parsing is identity.
        max_new_tokens: rng.range(1, 4097),
        // Multiples of 0.25 survive f32 -> f64 -> decimal -> f64 -> f32
        // exactly, keeping the round-trip equality strict.
        temperature: rng.below(9) as f32 * 0.25,
        stop_token: rng.bool(0.5).then(|| rng.below(256) as u32),
        deadline_ms: rng.bool(0.5).then(|| rng.range(1, 60_000) as u64),
        stream: rng.bool(0.5),
        // Grouped-request fields, inside their clamp ranges so parsing
        // stays identity (n in [1, 64]; best_of ≤ 64; beam_width ≤ 32).
        n: rng.range(1, 65) as u32,
        best_of: rng.below(65) as u32,
        beam_width: rng.below(33) as u32,
    }
}

/// Random `(sibling, siblings)` tags: half the time the plain-stream
/// defaults (0, 1) — whose rendering must omit both keys — and half the
/// time a grouped stream with a coherent `sibling < siblings`.
fn random_tags(rng: &mut Rng) -> (u32, u32) {
    if rng.bool(0.5) {
        let siblings = rng.range(2, 9) as u32;
        (rng.below(siblings as usize) as u32, siblings)
    } else {
        (0, 1)
    }
}

/// One random well-formed streaming frame plus its rendered line. The
/// frame variants cover every `event` the grammar defines; numeric
/// fields stick to values that survive the decimal round trip exactly.
fn random_frame(rng: &mut Rng) -> (StreamFrame, String) {
    let id = rng.below(1 << 20) as u64;
    let streamed = rng.below(512) as u64;
    match rng.below(5) {
        0 => {
            let seq = rng.below(4096) as u64;
            let token = rng.below(256) as u32;
            let sibling = if rng.bool(0.5) { rng.below(8) as u32 } else { 0 };
            let line = render_token_frame(id, seq, token, sibling, &ByteTokenizer);
            let text = ByteTokenizer.decode(&[token]);
            (StreamFrame::Token { id, seq, token, text, sibling }, line)
        }
        1 => {
            let tokens: Vec<u32> =
                (0..rng.below(8)).map(|_| rng.below(256) as u32).collect();
            let finish = if rng.bool(0.5) {
                FinishReason::Length
            } else {
                FinishReason::StopToken
            };
            let (sibling, siblings) = random_tags(rng);
            let resp = Response {
                id,
                tokens: tokens.clone(),
                finish,
                latency_ms: rng.below(4000) as f64 * 0.25,
                ttft_ms: rng.below(400) as f64 * 0.25,
                prompt_len: rng.range(1, 512),
                choices: Vec::new(),
            };
            let line = if siblings == 1 {
                render_done_frame(&resp, streamed, &ByteTokenizer)
            } else {
                let choice = Choice {
                    index: sibling,
                    tokens: tokens.clone(),
                    finish,
                    logprob: -(rng.below(400) as f64) * 0.25,
                };
                render_choice_done_frame(&resp, &choice, siblings, streamed, &ByteTokenizer)
            };
            let frame = StreamFrame::Done {
                id,
                tokens_streamed: streamed,
                finish: if finish == FinishReason::Length { "length" } else { "stop" }
                    .to_string(),
                text: ByteTokenizer.decode(&tokens),
                latency_ms: resp.latency_ms,
                ttft_ms: resp.ttft_ms,
                prompt_len: resp.prompt_len,
                sibling,
                siblings,
            };
            (frame, line)
        }
        2 => {
            let retry = rng.bool(0.5).then(|| rng.below(1000) as u64);
            let (sibling, siblings) = random_tags(rng);
            let line = render_stream_error_sibling(
                id, "worker_failed", "it broke", streamed, retry, sibling, siblings,
            );
            let frame = StreamFrame::Error {
                id,
                code: "worker_failed".to_string(),
                message: "it broke".to_string(),
                tokens_streamed: streamed,
                retry_after_ms: retry,
                sibling,
                siblings,
            };
            (frame, line)
        }
        3 => {
            let reason =
                ["deadline", "cancelled", "aborted", "timeout", "pruned"][rng.below(5)];
            let (sibling, siblings) = random_tags(rng);
            let line =
                render_cancelled_frame_sibling(id, reason, streamed, sibling, siblings);
            let frame = StreamFrame::Cancelled {
                id,
                reason: reason.to_string(),
                tokens_streamed: streamed,
                sibling,
                siblings,
            };
            (frame, line)
        }
        _ => (StreamFrame::Keepalive { id }, render_keepalive(id)),
    }
}

#[test]
fn random_byte_soup_never_panics() {
    let mut rng = Rng::new(0x50f7);
    for _ in 0..2000 {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_request(&line); // Err is fine; a panic fails the test
    }
}

#[test]
fn random_json_shaped_soup_never_panics() {
    // Soup biased toward JSON syntax characters reaches deeper into the
    // parser than uniform bytes do.
    let pool: &[u8] =
        b"{}[]\",:0123456789.eE+-truefalsnul\\/ promptmax_new_tokensbest_ofbeam_width";
    let mut rng = Rng::new(0x1234);
    for _ in 0..2000 {
        let len = rng.below(160);
        let bytes: Vec<u8> = (0..len).map(|_| pool[rng.below(pool.len())]).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_request(&line);
    }
}

#[test]
fn truncations_of_valid_lines_never_panic() {
    let mut rng = Rng::new(0x7a11);
    for _ in 0..200 {
        let line = render_request(&random_request(&mut rng));
        for cut in 0..line.len() {
            if line.is_char_boundary(cut) {
                let _ = parse_request(&line[..cut]);
            }
        }
    }
}

#[test]
fn byte_mutations_of_valid_lines_never_panic() {
    let mut rng = Rng::new(0xbeef);
    for _ in 0..500 {
        let line = render_request(&random_request(&mut rng));
        let mut bytes = line.into_bytes();
        for _ in 0..rng.range(1, 4) {
            let i = rng.below(bytes.len());
            bytes[i] = rng.below(256) as u8;
        }
        let mutated = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_request(&mutated);
    }
}

#[test]
fn oversized_nesting_is_rejected_not_overflowed() {
    // Without a parser depth limit these would overflow the stack.
    let deep_arrays = "[".repeat(50_000);
    assert!(parse_request(&deep_arrays).is_err());
    let deep_objects = "{\"p\":".repeat(50_000);
    assert!(parse_request(&deep_objects).is_err());
    assert!(Json::parse(&"[".repeat(50_000)).is_err());
}

#[test]
fn request_render_parse_round_trip() {
    let mut rng = Rng::new(0x7219);
    for _ in 0..500 {
        let req = random_request(&mut rng);
        let line = render_request(&req);
        let parsed = parse_request(&line)
            .unwrap_or_else(|e| panic!("round trip failed for {line:?}: {e}"));
        assert_eq!(parsed, req, "render->parse must be identity for {line:?}");
    }
}

// ---------------------------------------------------------------------
// Streaming frames: render ↔ parse identity for every event kind, and
// parse_frame must never panic on hostile bytes.
// ---------------------------------------------------------------------

#[test]
fn frame_render_parse_round_trip() {
    let mut rng = Rng::new(0xf4a3);
    for _ in 0..500 {
        let (frame, line) = random_frame(&mut rng);
        let parsed = parse_frame(&line)
            .unwrap_or_else(|e| panic!("frame round trip failed for {line:?}: {e}"));
        assert_eq!(parsed, frame, "render->parse must be identity for {line:?}");
    }
}

#[test]
fn frame_byte_soup_never_panics() {
    let mut rng = Rng::new(0x5eed);
    for _ in 0..2000 {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_frame(&line); // Err is fine; a panic fails the test
    }
    // Soup biased toward the frame grammar's own vocabulary reaches
    // deeper into the event dispatch than uniform bytes do.
    let pool: &[u8] = b"{}[]\",:0123456789.eE+-truefalsnul\\/ ideventtokenseqdone\
        errorcancelledkeepalivetokens_streamedfinishreasonsiblingsprunedlogprob";
    for _ in 0..2000 {
        let len = rng.below(160);
        let bytes: Vec<u8> = (0..len).map(|_| pool[rng.below(pool.len())]).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_frame(&line);
    }
}

// ---------------------------------------------------------------------
// Admin frames ({"cmd":"stats"}) and their replies: render ↔ parse
// identity for both encodings, and parse_admin / parse_stats_response
// must never panic on hostile bytes.
// ---------------------------------------------------------------------

/// A random snapshot-shaped payload: nested objects with exactly-
/// representable numbers (multiples of 0.25) and keys drawn from the
/// escape-heavy [`PROMPT_CHARS`] pool, so the render → parse identity
/// exercises the string escaper on both keys and values.
fn random_stats_payload(rng: &mut Rng) -> Json {
    let mut counters = Json::obj();
    for _ in 0..rng.range(1, 7) {
        let key: String = (0..rng.range(1, 12))
            .map(|_| PROMPT_CHARS[rng.below(PROMPT_CHARS.len())])
            .collect();
        counters.set(&key, ((rng.below(1 << 20)) as f64 * 0.25).into());
    }
    let buckets: Vec<Json> = (0..rng.below(4))
        .map(|i| {
            let mut b = Json::obj();
            b.set("ctx_log2", i.into())
                .set("mean_fraction", (rng.below(5) as f64 * 0.25).into());
            b
        })
        .collect();
    let mut o = Json::obj();
    o.set("ts_us", rng.below(1 << 30).into())
        .set("counters", counters)
        .set("fired_fraction", Json::Arr(buckets));
    o
}

#[test]
fn stats_request_render_parse_round_trip() {
    for format in [StatsFormat::Json, StatsFormat::Prometheus] {
        let line = render_stats_request(format);
        match parse_admin(&line) {
            Some(Ok(AdminCmd::Stats { format: parsed })) => assert_eq!(
                parsed, format,
                "render->parse must be identity for {line:?}"
            ),
            other => panic!("stats request {line:?} parsed as {other:?}"),
        }
    }
}

#[test]
fn stats_reply_render_parse_round_trip() {
    let mut rng = Rng::new(0x57a7);
    for _ in 0..500 {
        let payload = random_stats_payload(&mut rng);
        let line = render_stats_response(payload.clone());
        match parse_stats_response(&line) {
            Ok(StatsReply::Json(v)) => assert_eq!(
                v, payload,
                "render->parse must be identity for {line:?}"
            ),
            other => panic!("json stats reply {line:?} parsed as {other:?}"),
        }
        // Prometheus text with the same hostile character pool: the
        // exposition rides as one JSON string and must survive intact.
        let text: String = (0..rng.below(64))
            .map(|_| PROMPT_CHARS[rng.below(PROMPT_CHARS.len())])
            .collect();
        let line = render_stats_text_response(&text);
        match parse_stats_response(&line) {
            Ok(StatsReply::Text(t)) => assert_eq!(
                t, text,
                "render->parse must be identity for {line:?}"
            ),
            other => panic!("text stats reply {line:?} parsed as {other:?}"),
        }
    }
}

#[test]
fn admin_byte_soup_never_panics() {
    let mut rng = Rng::new(0xad41);
    for _ in 0..2000 {
        let len = rng.below(200);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_admin(&line); // None/Err is fine; a panic fails
        let _ = parse_stats_response(&line);
    }
    // Soup biased toward the admin grammar's own vocabulary reaches
    // deeper into the dispatch than uniform bytes do.
    let pool: &[u8] = b"{}[]\",:0123456789.eE+-truefalsnul\\/ \
        cmdstatsformatjsonprometheuseventtextcountersgaugeshistograms";
    for _ in 0..2000 {
        let len = rng.below(160);
        let bytes: Vec<u8> = (0..len).map(|_| pool[rng.below(pool.len())]).collect();
        let line = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_admin(&line);
        let _ = parse_stats_response(&line);
    }
}

#[test]
fn admin_truncations_and_mutations_never_panic() {
    let mut rng = Rng::new(0xface);
    let mut lines: Vec<String> = vec![
        render_stats_request(StatsFormat::Json),
        render_stats_request(StatsFormat::Prometheus),
    ];
    for _ in 0..50 {
        lines.push(render_stats_response(random_stats_payload(&mut rng)));
        lines.push(render_stats_text_response("# TYPE hsr_x counter\nhsr_x 1\n"));
    }
    for line in &lines {
        for cut in 0..line.len() {
            if line.is_char_boundary(cut) {
                let _ = parse_admin(&line[..cut]);
                let _ = parse_stats_response(&line[..cut]);
            }
        }
    }
    for _ in 0..500 {
        let mut bytes =
            lines[rng.below(lines.len())].clone().into_bytes();
        for _ in 0..rng.range(1, 4) {
            let i = rng.below(bytes.len());
            bytes[i] = rng.below(256) as u8;
        }
        let mutated = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_admin(&mutated);
        let _ = parse_stats_response(&mutated);
    }
}

#[test]
fn frame_truncations_and_mutations_never_panic() {
    let mut rng = Rng::new(0xd00d);
    for _ in 0..100 {
        let (_, line) = random_frame(&mut rng);
        for cut in 0..line.len() {
            if line.is_char_boundary(cut) {
                let _ = parse_frame(&line[..cut]);
            }
        }
    }
    for _ in 0..500 {
        let (_, line) = random_frame(&mut rng);
        let mut bytes = line.into_bytes();
        for _ in 0..rng.range(1, 4) {
            let i = rng.below(bytes.len());
            bytes[i] = rng.below(256) as u8;
        }
        let mutated = String::from_utf8_lossy(&bytes).into_owned();
        let _ = parse_frame(&mutated);
    }
}
