#!/usr/bin/env bash
# Repo verification: format, build, tests, and the perf smoke runs.
#
# Usage: scripts/verify.sh [--no-bench]
#
# Bench steps (machine-readable perf trajectory across PRs):
#  * benches/hsr_structures.rs --kernels-only → BENCH_kernels.json
#    (before/after ns-per-row for dot, scores_into, softmax row, prefill)
#  * benches/decode_time.rs --batched-only    → BENCH_decode.json
#    (ns per decoded token at batch 1/8/32, serial vs batched, per
#    HSR backend — the continuous-batch decode engine's headline)
#  * benches/e2e_serving.rs                   → stdout (steady-state
#    tok/s vs ttft; self-skips when model artifacts are absent)

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== kernel perf smoke (BENCH_kernels.json) =="
    cargo bench --bench hsr_structures -- --kernels-only
    echo "report: $(cd .. && pwd)/BENCH_kernels.json"

    echo "== batched decode smoke (BENCH_decode.json) =="
    cargo bench --bench decode_time -- --batched-only
    echo "report: $(cd .. && pwd)/BENCH_decode.json"

    echo "== serving throughput smoke (skips without artifacts) =="
    cargo bench --bench e2e_serving
fi

echo "verify: OK"
