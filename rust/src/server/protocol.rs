//! Wire protocol (JSON lines) for the serving front-end.
//!
//! One JSON object per line, in either direction. Success lines carry
//! `id`/`text`/`finish`/latency fields; error lines carry the schema
//! `{"error": <message>, "code": <short-code>, "retry_after_ms": <ms>?}`
//! (see the README "Failure model" section).

use crate::engine::{FinishReason, Response};
use crate::model::tokenizer::ByteTokenizer;
use crate::util::json::Json;
use anyhow::Result;

/// Parsed inbound request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub stop_token: Option<u32>,
    /// Relative deadline in milliseconds from receipt; the engine
    /// aborts the request past it with finish `"deadline"`.
    pub deadline_ms: Option<u64>,
}

/// Parse a request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = v.req_str("prompt")?.to_string();
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(64)
        .clamp(1, 4096);
    let temperature = v
        .get("temperature")
        .and_then(|x| x.as_f64())
        .unwrap_or(0.0) as f32;
    let stop_token = v
        .get("stop_token")
        .and_then(|x| x.as_usize())
        .map(|t| t as u32);
    let deadline_ms = v
        .get("deadline_ms")
        .and_then(|x| x.as_usize())
        .map(|ms| ms as u64);
    Ok(WireRequest { prompt, max_new_tokens, temperature, stop_token, deadline_ms })
}

/// Render a request line (the inverse of [`parse_request`] for values
/// already inside the clamped ranges — used by clients and the
/// round-trip property tests).
pub fn render_request(req: &WireRequest) -> String {
    let mut o = Json::obj();
    o.set("prompt", req.prompt.as_str().into())
        .set("max_new_tokens", req.max_new_tokens.into())
        .set("temperature", (req.temperature as f64).into());
    if let Some(t) = req.stop_token {
        o.set("stop_token", (t as usize).into());
    }
    if let Some(ms) = req.deadline_ms {
        o.set("deadline_ms", ms.into());
    }
    o.to_string()
}

/// Render a response line.
pub fn render_response(resp: &Response, tokenizer: &ByteTokenizer) -> String {
    let mut o = Json::obj();
    o.set("id", resp.id.into())
        .set("text", tokenizer.decode(&resp.tokens).into())
        .set("latency_ms", resp.latency_ms.into())
        .set("ttft_ms", resp.ttft_ms.into())
        .set("prompt_len", resp.prompt_len.into())
        .set(
            "finish",
            match resp.finish {
                FinishReason::Length => "length",
                FinishReason::StopToken => "stop",
                FinishReason::Aborted => "aborted",
                FinishReason::DeadlineExceeded => "deadline",
                FinishReason::Cancelled => "cancelled",
            }
            .into(),
        );
    o.to_string()
}

/// Render a structured error line: `error` (human message), `code`
/// (stable short code), optional `retry_after_ms` backpressure hint.
pub fn render_error(code: &str, message: &str, retry_after_ms: Option<u64>) -> String {
    let mut o = Json::obj();
    o.set("error", message.into()).set("code", code.into());
    if let Some(ms) = retry_after_ms {
        o.set("retry_after_ms", ms.into());
    }
    o.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_request() {
        let r = parse_request(
            r#"{"prompt":"hello","max_new_tokens":12,"temperature":0.5,"stop_token":46,"deadline_ms":1500}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, "hello");
        assert_eq!(r.max_new_tokens, 12);
        assert!((r.temperature - 0.5).abs() < 1e-6);
        assert_eq!(r.stop_token, Some(46));
        assert_eq!(r.deadline_ms, Some(1500));
    }

    #[test]
    fn defaults_and_validation() {
        let r = parse_request(r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 64);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.stop_token, None);
        assert_eq!(r.deadline_ms, None);
        assert!(parse_request(r#"{"prompt":""}"#).is_err());
        assert!(parse_request("not json").is_err());
        // max_new_tokens clamped.
        let r = parse_request(r#"{"prompt":"x","max_new_tokens":100000}"#).unwrap();
        assert_eq!(r.max_new_tokens, 4096);
    }

    #[test]
    fn render_roundtrips_through_json() {
        let resp = Response {
            id: 9,
            tokens: vec![104, 105],
            finish: FinishReason::Length,
            latency_ms: 1.5,
            ttft_ms: 0.5,
            prompt_len: 3,
        };
        let line = render_response(&resp, &ByteTokenizer);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req_str("text").unwrap(), "hi");
        assert_eq!(v.req_usize("id").unwrap(), 9);
        assert_eq!(v.req_str("finish").unwrap(), "length");
    }

    #[test]
    fn request_roundtrips_through_render() {
        let req = WireRequest {
            prompt: "say \"hi\"\n".to_string(),
            max_new_tokens: 7,
            temperature: 0.25,
            stop_token: Some(10),
            deadline_ms: Some(250),
        };
        let parsed = parse_request(&render_request(&req)).unwrap();
        assert_eq!(parsed, req);
    }

    #[test]
    fn new_finish_reasons_render() {
        let mut resp = Response {
            id: 1,
            tokens: vec![],
            finish: FinishReason::DeadlineExceeded,
            latency_ms: 0.0,
            ttft_ms: 0.0,
            prompt_len: 1,
        };
        let v = Json::parse(&render_response(&resp, &ByteTokenizer)).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "deadline");
        resp.finish = FinishReason::Cancelled;
        let v = Json::parse(&render_response(&resp, &ByteTokenizer)).unwrap();
        assert_eq!(v.req_str("finish").unwrap(), "cancelled");
    }

    #[test]
    fn error_lines_follow_schema() {
        let line = render_error("overloaded", "server overloaded", Some(50));
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.req_str("code").unwrap(), "overloaded");
        assert_eq!(v.req_str("error").unwrap(), "server overloaded");
        assert_eq!(v.req_usize("retry_after_ms").unwrap(), 50);
        let v = Json::parse(&render_error("bad_request", "nope", None)).unwrap();
        assert!(v.get("retry_after_ms").is_none());
    }
}
