//! Bench/reproduction: **Theorems 4.1 / 4.2** — generation decoding time,
//! HSR-sparse vs naive dense, across KV-cache sizes n.
//!
//! Claim shape: naive is O(mn), Algorithm 1 is O(mn^{4/5}); the sparse
//! curve's fitted exponent should sit well below the dense one's (~1.0)
//! and the speedup should widen with n.

use hsr_attn::attention::relu::relu_attention;
use hsr_attn::attention::softmax::softmax_attention;
use hsr_attn::attention::AttentionKind;
use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::engine::GenerationDecoding;
use hsr_attn::hsr::dynamic::DynamicHsr;
use hsr_attn::hsr::{build_hsr, gaussian_points, HalfSpaceReport, HsrBackend, QueryStats};
use hsr_attn::util::cli::Args;
use hsr_attn::util::json::Json;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::{fmt_ns, power_fit};
use hsr_attn::workloads::gaussian::AttentionInstance;

struct BatchCase {
    backend: &'static str,
    batch: usize,
    serial_ns_per_token: f64,
    batched_ns_per_token: f64,
}

impl BatchCase {
    fn speedup(&self) -> f64 {
        self.serial_ns_per_token / self.batched_ns_per_token
    }
}

/// Batched vs serial continuous-batch decode: B query rows over one KV
/// cache, `inference_row` loop (serial) against `inference_batch`
/// (fused union/bucket gathers + sharded worker threads). Outputs are
/// bit-identical (asserted in `engine::decode` tests); this measures the
/// wall-clock side and emits `BENCH_decode.json` at the repo root.
fn batched_decode_section(args: &Args, bench: &Bencher) {
    let d = args.usize_or("d", 8);
    let n = args.usize_or("batch-n", 65_536);
    let batches = args.usize_list_or("batches", &[1, 8, 32]);
    let backends = [HsrBackend::BallTree, HsrBackend::Projected, HsrBackend::Brute];
    let max_b = batches.iter().copied().max().unwrap_or(1);
    let mut rng = Rng::new(90);
    let inst = AttentionInstance::gaussian(&mut rng, max_b, n, d);
    let bias = inst.params.practical_bias(n) as f32;
    let kind = AttentionKind::Relu { alpha: 2, bias };

    println!("\n== batched vs serial decode, ReLU^2, n = {n}, d = {d} ==");
    println!(
        "{:>10} {:>6} | {:>14} {:>14} {:>8}",
        "backend", "B", "serial ns/tok", "batched ns/tok", "speedup"
    );
    let mut cases: Vec<BatchCase> = Vec::new();
    for backend in backends {
        let mut gd = GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, backend);
        for &b in &batches {
            let q = &inst.q[..b * d];
            let mut out = vec![0f32; b * d];
            let mut fired = vec![0usize; b];
            let serial = bench.run(&format!("serial/{}/b={b}", backend.name()), || {
                for i in 0..b {
                    let (s, e) = (i * d, (i + 1) * d);
                    black_box(gd.inference_row(&q[s..e], &mut out[s..e]));
                }
            });
            let batched = bench.run(&format!("batched/{}/b={b}", backend.name()), || {
                gd.inference_batch_into(q, &mut out, &mut fired);
                black_box(fired[0]);
            });
            let case = BatchCase {
                backend: backend.name(),
                batch: b,
                serial_ns_per_token: serial.median_ns / b as f64,
                batched_ns_per_token: batched.median_ns / b as f64,
            };
            println!(
                "{:>10} {:>6} | {:>14.1} {:>14.1} {:>7.2}x",
                case.backend,
                case.batch,
                case.serial_ns_per_token,
                case.batched_ns_per_token,
                case.speedup()
            );
            cases.push(case);
        }
    }

    // Machine-readable report at the repo root.
    let mut root = Json::obj();
    root.set("dispatch", hsr_attn::kernel::simd::dispatch_name().into());
    root.set(
        "threads",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).into(),
    );
    root.set("n", n.into());
    root.set("d", d.into());
    let items: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("backend", c.backend.into())
                .set("batch", c.batch.into())
                .set("serial_ns_per_token", c.serial_ns_per_token.into())
                .set("batched_ns_per_token", c.batched_ns_per_token.into())
                .set("speedup", c.speedup().into());
            o
        })
        .collect();
    root.set("cases", Json::Arr(items));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_decode.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

struct HsrBatchCase {
    backend: &'static str,
    fan_out: usize,
    looped_ns_per_query: f64,
    batched_ns_per_query: f64,
    looped_work_per_query: f64,
    batched_work_per_query: f64,
}

/// Batched vs looped multi-query HSR reporting: fan-out F queries over
/// one structure, `query_scored_into` loop against the shared-traversal
/// `query_many_scored_into` (identical outputs, asserted in the crate's
/// property tests). Reports both wall-clock and the `QueryStats::work`
/// proxy per query and emits `BENCH_hsr_batch.json` at the repo root.
fn hsr_batch_section(args: &Args, bench: &Bencher) {
    let d = args.usize_or("d", 8);
    let n = args.usize_or("hsr-n", 65_536);
    let fans = args.usize_list_or("fan-outs", &[1, 4, 16]);
    let mut rng = Rng::new(91);
    let points = gaussian_points(&mut rng, n, d, 1.0);
    // Dynamic backend: mostly batch-built, tail + small buckets grown by
    // inserts — the decode engine's steady state.
    let grown = n - n / 16;
    let mut dyn_hsr = DynamicHsr::from_points(HsrBackend::BallTree, &points[..grown * d], d);
    for j in grown..n {
        dyn_hsr.insert(&points[j * d..(j + 1) * d]);
    }
    let backends: Vec<(&'static str, Box<dyn HalfSpaceReport>)> = vec![
        ("balltree", build_hsr(HsrBackend::BallTree, &points, d)),
        ("projected", build_hsr(HsrBackend::Projected, &points, d)),
        ("dynamic", Box::new(dyn_hsr)),
        ("brute", build_hsr(HsrBackend::Brute, &points, d)),
    ];
    // Practical Lemma 6.1 threshold, raw-score units.
    let b_raw = ((0.4 * (n as f64).ln()).sqrt() * (d as f64).sqrt()) as f32;
    let max_fan = fans.iter().copied().max().unwrap_or(1);
    let queries = rng.gaussian_vec_f32(max_fan * d, 1.0);

    println!("\n== multi-query HSR fan-out, n = {n}, d = {d} ==");
    println!(
        "{:>10} {:>5} | {:>14} {:>14} {:>8} | {:>12} {:>12}",
        "backend", "F", "looped ns/q", "batched ns/q", "speedup", "looped w/q", "batched w/q"
    );
    let mut cases: Vec<HsrBatchCase> = Vec::new();
    for (name, be) in &backends {
        for &fan in &fans {
            let q = &queries[..fan * d];
            let bs = vec![b_raw; fan];
            let mut outs = vec![Vec::new(); fan];
            let mut scores = vec![Vec::new(); fan];
            let looped = bench.run(&format!("hsr-looped/{name}/f={fan}"), || {
                let mut stats = QueryStats::default();
                for i in 0..fan {
                    outs[i].clear();
                    scores[i].clear();
                    be.query_scored_into(
                        &q[i * d..(i + 1) * d],
                        b_raw,
                        &mut outs[i],
                        &mut scores[i],
                        &mut stats,
                    );
                }
                black_box(stats.reported);
            });
            let batched = bench.run(&format!("hsr-batched/{name}/f={fan}"), || {
                let mut stats = QueryStats::default();
                for o in outs.iter_mut() {
                    o.clear();
                }
                for s in scores.iter_mut() {
                    s.clear();
                }
                be.query_many_scored_into(q, &bs, &mut outs, &mut scores, &mut stats);
                black_box(stats.reported);
            });
            // Work counters, measured once per mode.
            let mut looped_stats = QueryStats::default();
            for i in 0..fan {
                outs[i].clear();
                scores[i].clear();
                be.query_scored_into(
                    &q[i * d..(i + 1) * d],
                    b_raw,
                    &mut outs[i],
                    &mut scores[i],
                    &mut looped_stats,
                );
            }
            let mut batched_stats = QueryStats::default();
            for o in outs.iter_mut() {
                o.clear();
            }
            for s in scores.iter_mut() {
                s.clear();
            }
            be.query_many_scored_into(q, &bs, &mut outs, &mut scores, &mut batched_stats);
            let case = HsrBatchCase {
                backend: *name,
                fan_out: fan,
                looped_ns_per_query: looped.median_ns / fan as f64,
                batched_ns_per_query: batched.median_ns / fan as f64,
                looped_work_per_query: looped_stats.work() as f64 / fan as f64,
                batched_work_per_query: batched_stats.work() as f64 / fan as f64,
            };
            println!(
                "{:>10} {:>5} | {:>14.1} {:>14.1} {:>7.2}x | {:>12.1} {:>12.1}",
                case.backend,
                case.fan_out,
                case.looped_ns_per_query,
                case.batched_ns_per_query,
                case.looped_ns_per_query / case.batched_ns_per_query,
                case.looped_work_per_query,
                case.batched_work_per_query
            );
            cases.push(case);
        }
    }

    let mut root = Json::obj();
    root.set("dispatch", hsr_attn::kernel::simd::dispatch_name().into());
    root.set("n", n.into());
    root.set("d", d.into());
    let items: Vec<Json> = cases
        .iter()
        .map(|c| {
            let mut o = Json::obj();
            o.set("backend", c.backend.into())
                .set("fan_out", c.fan_out.into())
                .set("looped_ns_per_query", c.looped_ns_per_query.into())
                .set("batched_ns_per_query", c.batched_ns_per_query.into())
                .set("speedup", (c.looped_ns_per_query / c.batched_ns_per_query).into())
                .set("looped_work_per_query", c.looped_work_per_query.into())
                .set("batched_work_per_query", c.batched_work_per_query.into());
            o
        })
        .collect();
    root.set("cases", Json::Arr(items));
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hsr_batch.json");
    match std::fs::write(path, root.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    banner("decode_time", "paper Theorems 4.1/4.2 (decode O(mn^{4/5}) vs O(mn))");
    let bench = Bencher::quick();
    if args.flag("hsr-batch-only") {
        hsr_batch_section(&args, &bench);
        return;
    }
    if args.flag("batched-only") {
        batched_decode_section(&args, &bench);
        return;
    }
    let d = args.usize_or("d", 8);
    let m = args.usize_or("m", 8);
    let ns = args.usize_list_or("ns", &[4_096, 16_384, 65_536, 262_144]);

    for (label, kind) in [
        ("ReLU^2 (Thm 4.1)", AttentionKind::Relu { alpha: 2, bias: 0.0 }),
        ("Softmax top-r (Thm 4.2)", AttentionKind::Softmax),
    ] {
        println!("\n== {label}, d = {d}, m = {m} ==");
        println!(
            "{:>9} | {:>11} {:>11} {:>8} | {:>9}",
            "n", "naive", "hsr", "speedup", "fired"
        );
        let mut xs = Vec::new();
        let mut dense_t = Vec::new();
        let mut sparse_t = Vec::new();
        for &n in &ns {
            let mut rng = Rng::new(n as u64);
            let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
            let bias = inst.params.practical_bias(n) as f32;
            let kind = match kind {
                AttentionKind::Relu { alpha, .. } => AttentionKind::Relu { alpha, bias },
                s => s,
            };
            // Naive dense baseline.
            let naive = bench.run(&format!("naive/n={n}"), || match kind {
                AttentionKind::Relu { alpha, bias } => {
                    black_box(relu_attention(&inst.q, &inst.k, &inst.v, d, alpha, bias));
                }
                AttentionKind::Softmax => {
                    black_box(softmax_attention(&inst.q, &inst.k, &inst.v, d));
                }
            });
            // Algorithm 1 (init outside the timed loop: the decoding
            // scenario amortizes INIT over the whole generation).
            // threads = 1: this section measures the single-threaded
            // algorithmic n^0.8 scaling; the batched section below is
            // where threading is benchmarked explicitly.
            let mut gd =
                GenerationDecoding::init(&inst.k, &inst.v, d, bias, kind, HsrBackend::BallTree);
            gd.threads = 1;
            if matches!(kind, AttentionKind::Softmax) {
                gd.top_r = Some((n as f64).powf(0.8) as usize);
                // Softmax needs b s.t. R ⊇ NN(r, q, K): calibrate from the
                // expected top-r quantile (Theorem 4.2's "choose b").
                let target = (n as f64).powf(0.8);
                gd.bias = hsr_attn::attention::threshold::practical_bias_for_target(
                    &inst.params,
                    n,
                    target * 2.0,
                ) as f32;
            }
            let sparse = bench.run(&format!("hsr/n={n}"), || {
                black_box(gd.inference(&inst.q));
            });
            let fired = {
                let mut out = vec![0f32; d];
                let q0: Vec<f32> = inst.query_row(0).to_vec();
                gd.inference_row(&q0, &mut out)
            };
            println!(
                "{:>9} | {:>11} {:>11} {:>7.2}x | {:>9}",
                n,
                fmt_ns(naive.median_ns),
                fmt_ns(sparse.median_ns),
                naive.median_ns / sparse.median_ns,
                fired
            );
            xs.push(n as f64);
            dense_t.push(naive.median_ns);
            sparse_t.push(sparse.median_ns);
        }
        if let (Some((ed, r2d)), Some((es, r2s))) =
            (power_fit(&xs, &dense_t), power_fit(&xs, &sparse_t))
        {
            println!(
                "fitted exponents: naive n^{ed:.2} (r2={r2d:.3})  hsr n^{es:.2} (r2={r2s:.3})"
            );
            println!("paper claim: naive ~n^1.0, Algorithm 1 ~n^0.8");
        }
    }

    // Figure-3 operating point: small fixed r (quality holds down to
    // r ≈ 2^4..2^6) — here sparse decoding wins outright because the
    // selected set, not the identification, dominates the dense cost.
    println!("\n== Softmax fixed top-r = 64 (Figure-3 operating point), d = {d}, m = {m} ==");
    println!("{:>9} | {:>11} {:>11} {:>8}", "n", "naive", "hsr", "speedup");
    for &n in &ns {
        let mut rng = Rng::new(n as u64 + 7);
        let inst = AttentionInstance::gaussian(&mut rng, m, n, d);
        let naive = bench.run(&format!("naive64/n={n}"), || {
            black_box(softmax_attention(&inst.q, &inst.k, &inst.v, d));
        });
        let mut gd = GenerationDecoding::init(
            &inst.k,
            &inst.v,
            d,
            0.0,
            AttentionKind::Softmax,
            HsrBackend::BallTree,
        );
        gd.threads = 1; // single-threaded: isolates the algorithmic win
        gd.top_r = Some(64);
        let sparse = bench.run(&format!("hsr64/n={n}"), || {
            black_box(gd.inference(&inst.q));
        });
        println!(
            "{:>9} | {:>11} {:>11} {:>7.2}x",
            n,
            fmt_ns(naive.median_ns),
            fmt_ns(sparse.median_ns),
            naive.median_ns / sparse.median_ns
        );
    }

    batched_decode_section(&args, &bench);
    hsr_batch_section(&args, &bench);
}
