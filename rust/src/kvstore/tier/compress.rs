//! Lossless f32 codec for cold KV payloads: XOR-delta over the raw bit
//! patterns, split into four byte planes, each plane zero-run-length
//! coded. Pure Rust, no dependencies, and **bit-exact**: every f32 —
//! NaN payloads, infinities, signed zeros, subnormals — round-trips to
//! the identical bit pattern, which is what lets a refaulted segment's
//! attention output be asserted bit-identical to the never-evicted one.
//!
//! Why this shape: consecutive K/V rows have correlated magnitudes, so
//! XOR-ing each word with its predecessor concentrates zeros in the
//! sign/exponent plane while mantissa planes stay near-incompressible.
//! On smooth payloads the ratio is large; on rough (gaussian-like)
//! payloads it degrades gracefully toward 1.0 instead of expanding —
//! the zero-run coder never emits more than `1 + varint` bytes of
//! overhead per literal run. Aggressive *lossy* cold-tier compression
//! (quantized spill) is a recorded follow-up, not this codec's job.

/// Append `v` as a LEB128 varint.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read a LEB128 varint at `*pos`, advancing it. `None` on truncation
/// or overlong (> 10 byte) encodings.
pub fn get_uvarint(bytes: &[u8], pos: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let &b = bytes.get(*pos)?;
        *pos += 1;
        if shift >= 64 {
            return None;
        }
        v |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

/// Zero-run-length code one byte plane: `0x00 <len>` for a zero run,
/// `0x01 <len> <bytes>` for a literal run. Literal runs swallow short
/// (< 4) zero gaps so the op stream never fragments into per-byte ops.
fn rle_encode(plane: &[u8], out: &mut Vec<u8>) {
    let n = plane.len();
    let mut i = 0usize;
    while i < n {
        if plane[i] == 0 {
            let mut j = i;
            while j < n && plane[j] == 0 {
                j += 1;
            }
            out.push(0);
            put_uvarint(out, (j - i) as u64);
            i = j;
        } else {
            // Extend the literal until a zero run of >= 4 begins (or end).
            let mut j = i;
            let mut zeros = 0usize;
            let mut end = n;
            while j < n {
                if plane[j] == 0 {
                    zeros += 1;
                    if zeros == 4 {
                        end = j + 1 - 4;
                        break;
                    }
                } else {
                    zeros = 0;
                }
                j += 1;
            }
            out.push(1);
            put_uvarint(out, (end - i) as u64);
            out.extend_from_slice(&plane[i..end]);
            i = end;
        }
    }
}

/// Sanity cap on the decoded element count: a corrupt length header must
/// not allocate unbounded memory. 2^28 f32s = 1 GiB, far above any
/// segment payload.
const MAX_ELEMS: u64 = 1 << 28;

/// Compress `data` (bit-exact) onto `out`. Self-delimiting: the matching
/// [`decompress_f32s`] call consumes exactly the bytes written here.
pub fn compress_f32s(data: &[f32], out: &mut Vec<u8>) {
    put_uvarint(out, data.len() as u64);
    if data.is_empty() {
        return;
    }
    let mut prev = 0u32;
    let deltas: Vec<u32> = data
        .iter()
        .map(|&f| {
            let bits = f.to_bits();
            let d = bits ^ prev;
            prev = bits;
            d
        })
        .collect();
    let mut plane_bytes = vec![0u8; data.len()];
    for plane in 0..4 {
        for (b, &d) in plane_bytes.iter_mut().zip(deltas.iter()) {
            *b = (d >> (8 * plane)) as u8;
        }
        rle_encode(&plane_bytes, out);
    }
}

/// Decompress one [`compress_f32s`] block at `*pos`, advancing it past
/// the block. `None` on any corruption (truncation, bad op tags, run
/// overflow) — callers treat that as a lost cold record, never a panic.
pub fn decompress_f32s(bytes: &[u8], pos: &mut usize) -> Option<Vec<f32>> {
    let n64 = get_uvarint(bytes, pos)?;
    if n64 > MAX_ELEMS {
        return None;
    }
    let n = n64 as usize;
    if n == 0 {
        return Some(Vec::new());
    }
    let mut deltas = vec![0u32; n];
    for plane in 0..4 {
        let mut produced = 0usize;
        while produced < n {
            let &tag = bytes.get(*pos)?;
            *pos += 1;
            let len = get_uvarint(bytes, pos)? as usize;
            if len == 0 || len > n - produced {
                return None;
            }
            match tag {
                0 => {}
                1 => {
                    let lit = bytes.get(*pos..*pos + len)?;
                    *pos += len;
                    for (slot, &b) in deltas[produced..produced + len].iter_mut().zip(lit) {
                        *slot |= u32::from(b) << (8 * plane);
                    }
                }
                _ => return None,
            }
            produced += len;
        }
    }
    let mut prev = 0u32;
    Some(
        deltas
            .iter()
            .map(|&d| {
                prev ^= d;
                f32::from_bits(prev)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(data: &[f32]) -> Vec<u8> {
        let mut buf = Vec::new();
        compress_f32s(data, &mut buf);
        let mut pos = 0usize;
        let back = decompress_f32s(&buf, &mut pos).expect("decodes");
        assert_eq!(pos, buf.len(), "block must be self-delimiting");
        assert_eq!(back.len(), data.len());
        for (a, b) in data.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "bit-exact roundtrip");
        }
        buf
    }

    #[test]
    fn roundtrip_gaussian_is_bit_exact() {
        let mut rng = Rng::new(41);
        for n in [1usize, 7, 64, 1000] {
            let data = rng.gaussian_vec_f32(n, 1.0);
            roundtrip(&data);
        }
    }

    #[test]
    fn roundtrip_special_values() {
        let data = vec![
            0.0f32,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN with payload
            f32::MIN_POSITIVE / 2.0,     // subnormal
            f32::MAX,
            f32::MIN,
            1.0,
            -1.0,
        ];
        roundtrip(&data);
        roundtrip(&[]);
    }

    #[test]
    fn smooth_data_compresses_hard() {
        // A constant run XOR-deltas to all-zero after the first word.
        let data = vec![3.25f32; 4096];
        let buf = roundtrip(&data);
        assert!(
            buf.len() < data.len(), // << 4 bytes/elem
            "constant payload must collapse ({} bytes for {} f32s)",
            buf.len(),
            data.len()
        );
    }

    #[test]
    fn rough_data_never_blows_up() {
        let mut rng = Rng::new(42);
        // Worst case: independent gaussians, random signs.
        let data = rng.gaussian_vec_f32(8192, 1.0);
        let buf = roundtrip(&data);
        // Overhead bound: 4 planes of (op tags + varints) stays well
        // under 10% above the raw 4 bytes/elem.
        assert!(buf.len() < data.len() * 4 + data.len() / 2);
    }

    #[test]
    fn corrupt_blocks_decode_to_none_not_panic() {
        let mut rng = Rng::new(43);
        let data = rng.gaussian_vec_f32(256, 1.0);
        let mut buf = Vec::new();
        compress_f32s(&data, &mut buf);
        // Truncations.
        for cut in [0usize, 1, buf.len() / 2, buf.len() - 1] {
            let mut pos = 0;
            let _ = decompress_f32s(&buf[..cut], &mut pos);
        }
        // Single-byte mutations: must decode to None or to *some* vec,
        // never panic.
        for i in 0..buf.len().min(200) {
            let mut mutated = buf.clone();
            mutated[i] ^= 0x55;
            let mut pos = 0;
            let _ = decompress_f32s(&mutated, &mut pos);
        }
        // A length header claiming 2^40 elements must be rejected.
        let mut bomb = Vec::new();
        put_uvarint(&mut bomb, 1 << 40);
        let mut pos = 0;
        assert!(decompress_f32s(&bomb, &mut pos).is_none());
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_uvarint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_uvarint(&buf, &mut pos), Some(v));
        }
        assert_eq!(pos, buf.len());
        assert_eq!(get_uvarint(&buf, &mut pos), None, "exhausted");
    }
}
