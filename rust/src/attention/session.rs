//! The unified sparse-attention session — one plan→execute contract for
//! every consumer of the paper's two-phase pipeline.
//!
//! The paper's Algorithms 1 and 2 share a single shape: HSR reports the
//! fired set for each query (phase A), then the attention is evaluated
//! on exactly that set (phase B). This module makes the split explicit
//! and *the* API:
//!
//! ```text
//! AttentionConfig::new(kind, backend)     // builder: threshold, top-r,
//!     .with_bias(b).with_threads(t)       //   adaptive policy, threads
//!     .build(keys, d)                     // -> AttentionSession
//! session.plan(queries)                   // -> AttentionPlan (fired sets
//!                                         //    + carried scores + stats)
//! session.execute(&mut plan, values, out) // bucketed value gather
//! session.run(q, values, out, fired)      // sharded plan+execute
//! ```
//!
//! `PromptPrefilling`, `GenerationDecoding`, the transformer's per-head
//! attention and the serving engine are all thin callers of this type;
//! their legacy constructors remain as deprecated shims for one release.
//!
//! **Multi-query fan-out.** Planning batches query rows into
//! [`QUERY_BLOCK`]-row blocks and answers each block with one
//! [`HalfSpaceReport::query_many_scored_into`] call, so tree-shaped
//! backends prune each node once against the whole block (the ROADMAP's
//! cross-sequence HSR amortization). Blocks are aligned to global row
//! indices regardless of the worker count, so the shared-traversal
//! [`QueryStats`] are deterministic across thread counts. Evaluation is
//! canonicalized to ascending key order per row, which makes the final
//! output independent of the backend's traversal order *and* of how
//! rows are grouped — planning through this session is bit-identical to
//! the pre-session decode paths for every backend and thread count.

use crate::attention::plan::AttentionPlan;
use crate::attention::relu::relu_weights_in_place;
use crate::attention::threshold::ThresholdParams;
use crate::attention::topk::{rth_largest, top_r_select_into};
use crate::attention::AttentionKind;
use crate::hsr::dynamic::DynamicHsr;
use crate::hsr::{HalfSpaceReport, HsrBackend, QueryStats};
use crate::kernel::simd;
use crate::kernel::Scratch;

/// Rows per shared-traversal HSR query block. Blocks are aligned to
/// multiples of this value across the whole batch (shards round their
/// row counts up to it), so work counters do not depend on threading.
pub const QUERY_BLOCK: usize = 8;

/// How many value rows one union bucket packs per gather pass of the
/// execute phase: small enough that the packed tile stays L1/L2
/// resident while every row of the batch consumes it.
pub const BUCKET_ROWS: usize = 256;

/// How the session resolves the HSR threshold b (on the scaled score).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ThresholdPolicy {
    /// Lemma 6.1's practical threshold σ_q σ_k √(0.4 ln n), resolved
    /// from the indexed key count when the session is built.
    Lemma,
    /// An explicit threshold on the scaled score ⟨q,k⟩/√d.
    Fixed(f32),
}

/// Builder for an [`AttentionSession`]: every knob that used to be
/// scattered across `EngineConfig`, `GenerationDecoding::init` and
/// `PromptPrefilling::new`, in one place.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionConfig {
    /// Which attention to evaluate on the reported set.
    pub kind: AttentionKind,
    /// Static HSR backend the session's dynamic index is built over.
    pub backend: HsrBackend,
    /// Softmax: restrict each report to its top-r entries (Theorem 4.2);
    /// None → evaluate the whole reported set.
    pub top_r: Option<usize>,
    /// Threshold policy for the HSR half-space query.
    pub threshold: ThresholdPolicy,
    /// Per-query adaptive threshold for softmax top-r rows: aim the
    /// expected report at 2r given key entry std `sigma_k` (a fixed b
    /// under-reports for small-norm queries and triggers costly
    /// full-scan fallbacks). None → use the fixed/Lemma bias for every
    /// row. Ignored for ReLU and for softmax without top-r.
    pub adaptive_sigma_k: Option<f64>,
    /// Worker threads for `run`: 0 → one per available core, 1 → serial.
    /// Output and stats are identical for every setting.
    pub threads: usize,
}

impl AttentionConfig {
    pub fn new(kind: AttentionKind, backend: HsrBackend) -> AttentionConfig {
        AttentionConfig {
            kind,
            backend,
            top_r: None,
            threshold: ThresholdPolicy::Lemma,
            adaptive_sigma_k: None,
            threads: 0,
        }
    }

    pub fn with_top_r(mut self, r: usize) -> AttentionConfig {
        self.top_r = Some(r);
        self
    }

    pub fn with_threshold(mut self, t: ThresholdPolicy) -> AttentionConfig {
        self.threshold = t;
        self
    }

    /// Shorthand for `with_threshold(ThresholdPolicy::Fixed(b))`.
    pub fn with_bias(mut self, b: f32) -> AttentionConfig {
        self.threshold = ThresholdPolicy::Fixed(b);
        self
    }

    pub fn with_adaptive(mut self, sigma_k: f64) -> AttentionConfig {
        self.adaptive_sigma_k = Some(sigma_k);
        self
    }

    pub fn with_threads(mut self, t: usize) -> AttentionConfig {
        self.threads = t;
        self
    }

    /// Build a session over `n = keys.len() / d` key rows.
    pub fn build(&self, keys: &[f32], d: usize) -> AttentionSession {
        AttentionSession::build(*self, keys, d)
    }
}

/// Copyable per-plan snapshot of the row-evaluation configuration, so
/// worker threads never borrow the session itself.
#[derive(Clone, Copy)]
pub(crate) struct RowPolicy {
    pub d: usize,
    pub n: usize,
    /// Threshold on the scaled score (also the ReLU bias).
    pub bias: f32,
    pub kind: AttentionKind,
    pub top_r: Option<usize>,
    pub adaptive_sigma_k: Option<f64>,
}

/// A built sparse-attention session: the dynamic HSR index over the keys
/// plus the evaluation policy. `plan` answers queries (phase A),
/// `execute` evaluates a plan against a value matrix (phase B), `run`
/// does both with row sharding across scoped worker threads.
pub struct AttentionSession {
    /// Which attention to evaluate on the reported set.
    pub kind: AttentionKind,
    /// Softmax: keep only the top-r of each report.
    pub top_r: Option<usize>,
    /// Resolved threshold on the scaled score (the b of Lemma 6.1).
    pub bias: f32,
    /// See [`AttentionConfig::adaptive_sigma_k`].
    pub adaptive_sigma_k: Option<f64>,
    /// Worker threads for `run` (0 → auto, 1 → serial).
    pub threads: usize,
    /// Work counters accumulated by [`AttentionSession::run`] calls.
    /// The explicit `plan`/`plan_into` flow reports its counters on the
    /// returned [`AttentionPlan::stats`] instead (those entry points
    /// take `&self`, so several plans can run concurrently).
    pub stats: QueryStats,
    /// Softmax top-r full-scan fallbacks accumulated by `run` calls;
    /// per-plan counts live on [`AttentionPlan::fallbacks`].
    pub fallbacks: usize,
    index: DynamicHsr,
    d: usize,
    /// Per-worker plan arenas, reused across `run` calls.
    pool: Vec<AttentionPlan>,
}

impl AttentionSession {
    fn build(cfg: AttentionConfig, keys: &[f32], d: usize) -> AttentionSession {
        assert!(d > 0);
        assert_eq!(keys.len() % d, 0);
        let n = keys.len() / d;
        let bias = match (cfg.threshold, cfg.kind) {
            (ThresholdPolicy::Fixed(b), _) => b,
            // ReLU exactness requires query threshold == weight bias, so
            // the Lemma policy resolves to the kind's own bias — the
            // user-stated b of Definition 1.2 — rather than silently
            // substituting the Gaussian-workload value.
            (ThresholdPolicy::Lemma, AttentionKind::Relu { bias, .. }) => bias,
            (ThresholdPolicy::Lemma, AttentionKind::Softmax) => {
                ThresholdParams::standard(d, 1).practical_bias(n.max(2)) as f32
            }
        };
        AttentionSession {
            kind: cfg.kind,
            top_r: cfg.top_r,
            bias,
            adaptive_sigma_k: cfg.adaptive_sigma_k,
            threads: cfg.threads,
            stats: QueryStats::default(),
            fallbacks: 0,
            index: DynamicHsr::from_points(cfg.backend, keys, d),
            d,
            pool: Vec::new(),
        }
    }

    /// Number of indexed key rows.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Key dimensionality d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// The session's dynamic HSR index (diagnostics / direct queries).
    pub fn index(&self) -> &DynamicHsr {
        &self.index
    }

    /// Fraction of reported points that arrived via whole-subtree bulk
    /// reports (no per-point inner product) across all `run` calls so
    /// far — the output-sensitivity Corollary 3.1 buys. Guarded: 0.0
    /// before any query.
    pub fn bulk_report_fraction(&self) -> f64 {
        crate::obs::telemetry::ratio_or(
            self.stats.bulk_reported as f64,
            self.stats.reported as f64,
            0.0,
        )
    }

    /// Accumulated work counters plus fallbacks as JSON, for trace
    /// dumps and diagnostics.
    pub fn telemetry_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("nodes_visited", self.stats.nodes_visited.into())
            .set("points_scanned", self.stats.points_scanned.into())
            .set("bulk_reported", self.stats.bulk_reported.into())
            .set("reported", self.stats.reported.into())
            .set("fallbacks", self.fallbacks.into())
            .set("bulk_report_fraction", self.bulk_report_fraction().into());
        o
    }

    /// Append a generated token's key — Theorem D.2's auto-regressive
    /// growth, amortized-logarithmic via the dynamic index.
    pub fn append_key(&mut self, key: &[f32]) {
        assert_eq!(key.len(), self.d);
        self.index.insert(key);
    }

    fn row_policy(&self) -> RowPolicy {
        RowPolicy {
            d: self.d,
            n: self.len(),
            bias: self.bias,
            kind: self.kind,
            top_r: self.top_r,
            adaptive_sigma_k: self.adaptive_sigma_k,
        }
    }

    /// Phase A for `q.len() / d` query rows, allocating a fresh plan.
    pub fn plan(&self, q: &[f32]) -> AttentionPlan {
        let mut plan = AttentionPlan::new();
        self.plan_into(q, &mut plan);
        plan
    }

    /// Phase A into a reusable plan arena (no steady-state allocation).
    pub fn plan_into(&self, q: &[f32], plan: &mut AttentionPlan) {
        plan_rows(&self.index, self.row_policy(), q, plan);
    }

    /// Phase B: evaluate a plan against `values` ([n, d], row-major),
    /// writing the [rows, d] attention output. Bucketed union gather —
    /// the value matrix streams through the kernel layer once per
    /// [`BUCKET_ROWS`]-sized bucket instead of once per row.
    pub fn execute(&self, plan: &mut AttentionPlan, values: &[f32], out: &mut [f32]) {
        assert_eq!(values.len(), self.len() * self.d);
        assert_eq!(out.len(), plan.rows() * self.d);
        execute_plan(plan, values, self.d, out);
    }

    /// plan + execute over B query rows, sharded across scoped worker
    /// threads ([`AttentionSession::threads`]); writes the [B, d] output
    /// into `out` and the per-row activated-set sizes k̃_i into `fired`.
    /// Output, fired counts and merged stats are bit-identical for every
    /// thread count.
    pub fn run(&mut self, q: &[f32], values: &[f32], out: &mut [f32], fired: &mut [usize]) {
        let d = self.d;
        assert_eq!(q.len() % d, 0);
        let b = q.len() / d;
        assert_eq!(out.len(), b * d);
        assert_eq!(fired.len(), b);
        assert_eq!(values.len(), self.len() * d);
        if b == 0 {
            return;
        }
        let pol = self.row_policy();
        let workers = crate::kernel::effective_threads(self.threads, b);
        // Shard on QUERY_BLOCK boundaries: the block partition — and so
        // the shared-traversal stats — is independent of worker count.
        let base = (b + workers - 1) / workers;
        let rows_per = ((base + QUERY_BLOCK - 1) / QUERY_BLOCK) * QUERY_BLOCK;
        let shards = (b + rows_per - 1) / rows_per;
        while self.pool.len() < shards {
            self.pool.push(AttentionPlan::new());
        }
        let index = &self.index;
        let pool = &mut self.pool[..shards];
        if shards <= 1 {
            let plan = &mut pool[0];
            plan_rows(index, pol, q, plan);
            execute_plan(plan, values, d, out);
            fired.copy_from_slice(&plan.fired);
        } else {
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(shards);
                for (((q_c, out_c), fired_c), plan) in q
                    .chunks(rows_per * d)
                    .zip(out.chunks_mut(rows_per * d))
                    .zip(fired.chunks_mut(rows_per))
                    .zip(pool.iter_mut())
                {
                    handles.push(scope.spawn(move || {
                        plan_rows(index, pol, q_c, plan);
                        execute_plan(plan, values, d, out_c);
                        fired_c.copy_from_slice(&plan.fired);
                    }));
                }
                for h in handles {
                    h.join().expect("attention session worker panicked");
                }
            });
        }
        // Merge in shard order so the aggregate is deterministic.
        for plan in pool.iter() {
            self.stats.add(&plan.stats);
            self.fallbacks += plan.fallbacks;
        }
    }
}

/// The per-row HSR threshold on the *raw* inner product ⟨q,k⟩.
fn row_threshold(pol: RowPolicy, qi: &[f32]) -> f32 {
    match (pol.kind, pol.top_r, pol.adaptive_sigma_k) {
        // Softmax top-r with adaptive policy: ⟨q,k⟩ | q ~ N(0, ‖q‖²σ_k²),
        // so aiming the expected report at 2r needs
        // b_raw = ‖q‖ σ_k √(2 ln(n / 2r)).
        (AttentionKind::Softmax, Some(r), Some(sigma_k)) => {
            let n = pol.n.max(2) as f64;
            let target = (2 * r).max(1) as f64;
            let t = (2.0 * (n / target).ln()).max(0.0).sqrt();
            (crate::hsr::norm(qi) as f64 * sigma_k * t) as f32
        }
        _ => pol.bias * (pol.d as f32).sqrt(),
    }
}

/// Canonicalize an (index, score) report to ascending key order,
/// writing into `selected` / `exps`. Evaluation order is then
/// independent of the backend's traversal order AND of how rows are
/// grouped into batches — the property the bit-identity rests on.
fn canonicalize_ascending(
    fire: &[u32],
    scores: &[f32],
    perm: &mut Vec<u32>,
    selected: &mut Vec<u32>,
    exps: &mut Vec<f32>,
) {
    perm.clear();
    perm.extend(0..fire.len() as u32);
    perm.sort_unstable_by_key(|&p| fire[p as usize]);
    selected.clear();
    exps.clear();
    for &p in perm.iter() {
        selected.push(fire[p as usize]);
        exps.push(scores[p as usize]);
    }
}

/// Finish one row whose block query already reported into
/// `fire`/`scores`: softmax top-r under-report fallback, canonical
/// ascending-index selection, and the in-place weight transform.
/// Returns (k̃, 1/normalizer) — 0.0 marking a degenerate zero row.
#[allow(clippy::too_many_arguments)]
fn finish_row(
    index: &dyn HalfSpaceReport,
    pol: RowPolicy,
    qi: &[f32],
    fire: &mut Vec<u32>,
    scores: &mut Vec<f32>,
    selected: &mut Vec<u32>,
    exps: &mut Vec<f32>,
    perm: &mut Vec<u32>,
    stats: &mut QueryStats,
    fallbacks: &mut usize,
) -> (usize, f32) {
    let inv_sqrt_d = 1.0 / (pol.d as f32).sqrt();
    if let (AttentionKind::Softmax, Some(r)) = (pol.kind, pol.top_r) {
        // Theorem 4.2 needs R = NN(r, q, K): if the threshold
        // under-reported (|fire| < r), fall back to the full half-space
        // so the top-r below is exact.
        if fire.len() < r.min(pol.n) {
            *fallbacks += 1;
            fire.clear();
            scores.clear();
            index.query_scored_into(qi, f32::NEG_INFINITY, fire, scores, stats);
        }
    }
    match (pol.kind, pol.top_r) {
        (AttentionKind::Softmax, Some(r)) if r < fire.len() => {
            top_r_select_into(fire, scores, r, selected, exps);
        }
        _ => canonicalize_ascending(fire, scores, perm, selected, exps),
    }
    for s in exps.iter_mut() {
        *s *= inv_sqrt_d;
    }
    let denom = match pol.kind {
        // The session's resolved bias governs the ReLU weights — it is
        // the same b the HSR query fired on, which is what makes the
        // ReLU evaluation exact (Definition 1.2).
        AttentionKind::Relu { alpha, bias } => {
            debug_assert!(
                (bias - pol.bias).abs() < 1e-6,
                "ReLU bias must equal the session threshold for exactness"
            );
            relu_weights_in_place(exps, alpha, pol.bias)
        }
        AttentionKind::Softmax => simd::softmax_exp_in_place(exps),
    };
    let inv = if denom > 0.0 && denom.is_finite() { 1.0 / denom } else { 0.0 };
    (selected.len(), inv)
}

/// Phase A over all rows of `q`: block the rows into [`QUERY_BLOCK`]s,
/// answer each block with one shared HSR traversal, then finish each
/// row into the plan's CSR arrays.
pub(crate) fn plan_rows(
    index: &dyn HalfSpaceReport,
    pol: RowPolicy,
    q: &[f32],
    plan: &mut AttentionPlan,
) {
    let d = pol.d;
    assert_eq!(q.len() % d, 0);
    let rows = q.len() / d;
    plan.reset();
    let AttentionPlan { buf, fired, stats, fallbacks } = plan;
    let mut r0 = 0usize;
    while r0 < rows {
        let bl = QUERY_BLOCK.min(rows - r0);
        let qblock = &q[r0 * d..(r0 + bl) * d];
        buf.bs.clear();
        for t in 0..bl {
            buf.bs.push(row_threshold(pol, &qblock[t * d..(t + 1) * d]));
        }
        while buf.many_idx.len() < bl {
            buf.many_idx.push(Vec::new());
            buf.many_scores.push(Vec::new());
        }
        for t in 0..bl {
            buf.many_idx[t].clear();
            buf.many_scores[t].clear();
        }
        index.query_many_scored_into(
            qblock,
            &buf.bs,
            &mut buf.many_idx[..bl],
            &mut buf.many_scores[..bl],
            stats,
        );
        for t in 0..bl {
            let qi = &qblock[t * d..(t + 1) * d];
            let Scratch { many_idx, many_scores, selected, exps, perm, idx, w, row_ptr, inv, .. } =
                buf;
            let (k, rinv) = finish_row(
                index,
                pol,
                qi,
                &mut many_idx[t],
                &mut many_scores[t],
                selected,
                exps,
                perm,
                stats,
                fallbacks,
            );
            fired.push(k);
            idx.extend_from_slice(selected);
            w.extend_from_slice(exps);
            row_ptr.push(idx.len());
            inv.push(rinv);
        }
        r0 += bl;
    }
}

/// Single calibrated softmax top-r row — the transformer's per-head
/// policy (Theorem 4.2's "choose b such that R = NN(r, q, K)" realized
/// by quantile recalibration). Queries with the carried-in threshold,
/// falls back to the full half-space on a calibration miss, and returns
/// the recalibrated threshold (aimed at ~`slack`·r candidates) for the
/// caller to store. The planned row is ready for `execute`.
pub(crate) fn plan_top_r_row(
    index: &dyn HalfSpaceReport,
    qi: &[f32],
    r: usize,
    calib: Option<f32>,
    slack: f32,
    plan: &mut AttentionPlan,
) -> Option<f32> {
    let d = qi.len();
    plan.reset();
    let AttentionPlan { buf, fired, stats, fallbacks } = plan;
    let Scratch { fire, scores, selected, exps, perm, idx, w, row_ptr, inv, .. } = buf;
    fire.clear();
    scores.clear();
    let b_raw = calib.unwrap_or(f32::NEG_INFINITY);
    index.query_scored_into(qi, b_raw, fire, scores, stats);
    if fire.len() < r {
        // Calibration miss: fall back to the full half-space (b = -inf ≡
        // brute top-r) so exactness is never compromised.
        *fallbacks += 1;
        fire.clear();
        scores.clear();
        index.query_scored_into(qi, f32::NEG_INFINITY, fire, scores, stats);
    }
    // Recalibrate from the raw candidate scores before they are consumed.
    let target = ((r as f32 * slack) as usize).min(fire.len());
    let new_calib = if target >= 1 { Some(rth_largest(scores, target)) } else { None };
    if r < fire.len() {
        top_r_select_into(fire, scores, r, selected, exps);
    } else {
        canonicalize_ascending(fire, scores, perm, selected, exps);
    }
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for s in exps.iter_mut() {
        *s *= inv_sqrt_d;
    }
    let denom = simd::softmax_exp_in_place(exps);
    let rinv = if denom > 0.0 && denom.is_finite() { 1.0 / denom } else { 0.0 };
    fired.push(selected.len());
    idx.extend_from_slice(selected);
    w.extend_from_slice(exps);
    row_ptr.push(idx.len());
    inv.push(rinv);
    new_calib
}

/// Calibrated softmax top-r planning for a **shared-prefix group**: the
/// decode rows of several sequences whose KV caches share a chain of
/// immutable prefix segments (each a [`HalfSpaceReport`] with a global
/// start offset) and differ only in their private tails.
///
/// Phase A runs ONE multi-query traversal per shared segment for the
/// whole member block — the cross-sequence amortization of
/// [`HalfSpaceReport::query_many_scored_into`] — then scans each
/// member's private tail individually. Per member it then applies
/// exactly the [`plan_top_r_row`] finish: full-half-space fallback when
/// the carried threshold under-reported (`|fire| < r` — Theorem 4.2's
/// exactness guard, so the selected set is always the true top-r and
/// shared-vs-unshared outputs stay bit-identical), quantile
/// recalibration aimed at `slack · r` candidates, canonical
/// ascending-index top-r selection, and the in-place softmax transform.
/// One CSR row per member is appended to `plan` in member order; the
/// member queries must already be packed into `plan.buf.qblock`
/// (`[members, d]`, row-major). `new_calibs[i]` receives member i's
/// recalibrated threshold (None when nothing could be calibrated).
#[allow(clippy::too_many_arguments)]
pub(crate) fn plan_top_r_shared(
    prefix: &[(&dyn HalfSpaceReport, usize)],
    prefix_len: usize,
    d: usize,
    tails: &[&dyn HalfSpaceReport],
    rs: &[usize],
    calibs: &[Option<f32>],
    slack: f32,
    plan: &mut AttentionPlan,
    new_calibs: &mut Vec<Option<f32>>,
) {
    let b = tails.len();
    assert_eq!(rs.len(), b);
    assert_eq!(calibs.len(), b);
    plan.reset();
    new_calibs.clear();
    let AttentionPlan { buf, fired, stats, fallbacks } = plan;
    assert_eq!(buf.qblock.len(), b * d, "qblock must hold the member queries");
    buf.bs.clear();
    for c in calibs {
        buf.bs.push(c.unwrap_or(f32::NEG_INFINITY));
    }
    while buf.many_idx.len() < b {
        buf.many_idx.push(Vec::new());
        buf.many_scores.push(Vec::new());
    }
    for t in 0..b {
        buf.many_idx[t].clear();
        buf.many_scores[t].clear();
    }
    // Shared phase: one block traversal per chain segment, local report
    // ids remapped to global key indices by the segment's start offset.
    for &(part, start) in prefix {
        buf.cursor.clear();
        for t in 0..b {
            buf.cursor.push(buf.many_idx[t].len());
        }
        part.query_many_scored_into(
            &buf.qblock,
            &buf.bs,
            &mut buf.many_idx[..b],
            &mut buf.many_scores[..b],
            stats,
        );
        if start > 0 {
            for t in 0..b {
                let from = buf.cursor[t];
                for x in &mut buf.many_idx[t][from..] {
                    *x += start as u32;
                }
            }
        }
    }
    // Private phase: each member's tail, remapped past the prefix.
    for t in 0..b {
        let before = buf.many_idx[t].len();
        tails[t].query_scored_into(
            &buf.qblock[t * d..(t + 1) * d],
            buf.bs[t],
            &mut buf.many_idx[t],
            &mut buf.many_scores[t],
            stats,
        );
        for x in &mut buf.many_idx[t][before..] {
            *x += prefix_len as u32;
        }
    }
    // Finish each member row exactly like `plan_top_r_row`.
    let inv_sqrt_d = 1.0 / (d as f32).sqrt();
    for t in 0..b {
        let Scratch {
            qblock, many_idx, many_scores, selected, exps, perm, idx, w, row_ptr, inv, ..
        } = buf;
        let fire = &mut many_idx[t];
        let scores = &mut many_scores[t];
        let qi = &qblock[t * d..(t + 1) * d];
        let r = rs[t];
        if fire.len() < r {
            // Calibration miss: fall back to the full half-space over
            // the whole chain + tail so top-r exactness is preserved.
            *fallbacks += 1;
            fire.clear();
            scores.clear();
            for &(part, start) in prefix {
                let before = fire.len();
                part.query_scored_into(qi, f32::NEG_INFINITY, fire, scores, stats);
                if start > 0 {
                    for x in &mut fire[before..] {
                        *x += start as u32;
                    }
                }
            }
            let before = fire.len();
            tails[t].query_scored_into(qi, f32::NEG_INFINITY, fire, scores, stats);
            for x in &mut fire[before..] {
                *x += prefix_len as u32;
            }
        }
        let target = ((r as f32 * slack) as usize).min(fire.len());
        new_calibs.push(if target >= 1 { Some(rth_largest(scores, target)) } else { None });
        if r < fire.len() {
            top_r_select_into(fire, scores, r, selected, exps);
        } else {
            canonicalize_ascending(fire, scores, perm, selected, exps);
        }
        for s in exps.iter_mut() {
            *s *= inv_sqrt_d;
        }
        let denom = simd::softmax_exp_in_place(exps);
        let rinv = if denom > 0.0 && denom.is_finite() { 1.0 / denom } else { 0.0 };
        fired.push(selected.len());
        idx.extend_from_slice(selected);
        w.extend_from_slice(exps);
        row_ptr.push(idx.len());
        inv.push(rinv);
    }
}

/// Resolver mapping a plan's global key index to its value row. This is
/// the hook segmented KV storage (shared prefix chain + private tail)
/// plugs into the execute phase; contiguous storage is just the
/// identity resolver over one value matrix.
pub(crate) trait ValueRows {
    fn value_row(&self, j: usize) -> &[f32];
}

/// Phase B for one planned row against *resolved* value storage: the
/// weighted axpy accumulation in ascending key order — float-for-float
/// the single-row branch of [`execute_plan`], so shared-prefix rows are
/// bit-identical to contiguous-storage rows.
pub(crate) fn execute_plan_row_resolved(
    plan: &AttentionPlan,
    row: usize,
    d: usize,
    values: &dyn ValueRows,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), d);
    out.fill(0.0);
    let buf = &plan.buf;
    let scale = buf.inv[row];
    if scale == 0.0 {
        return;
    }
    for c in buf.row_ptr[row]..buf.row_ptr[row + 1] {
        let a = buf.w[c];
        if a != 0.0 {
            let j = buf.idx[c] as usize;
            simd::axpy(out, values.value_row(j), a * scale);
        }
    }
}

/// Phase B: bucketed union gather. Union the plan's fired indices and
/// stream the value matrix once per [`BUCKET_ROWS`]-row bucket,
/// accumulating every row's weighted sum out of the packed (cache-hot)
/// bucket instead of issuing `rows` independent scattered passes over V.
/// Each row's contributions are applied in ascending key order
/// regardless of bucketing, so the result is independent of batching.
pub(crate) fn execute_plan(plan: &mut AttentionPlan, values: &[f32], d: usize, out: &mut [f32]) {
    let rows = plan.rows();
    debug_assert_eq!(out.len(), rows * d);
    out.fill(0.0);
    let Scratch { idx, w, row_ptr, inv, union_idx, packed, cursor, .. } = &mut plan.buf;
    if rows == 1 {
        // Single row (the per-token transformer path and B = 1 decode):
        // the union IS the row, so skip the pack entirely and axpy
        // straight out of `values`. Same ascending order and identical
        // floats as the bucketed path below — bit-identical outputs.
        if inv[0] == 0.0 {
            return;
        }
        let scale = inv[0];
        for c in row_ptr[0]..row_ptr[1] {
            let a = w[c];
            if a != 0.0 {
                let j = idx[c] as usize;
                simd::axpy(out, &values[j * d..(j + 1) * d], a * scale);
            }
        }
        return;
    }
    union_idx.clear();
    union_idx.extend_from_slice(idx);
    union_idx.sort_unstable();
    union_idx.dedup();
    cursor.clear();
    cursor.extend_from_slice(&row_ptr[..rows]);
    for bucket in union_idx.chunks(BUCKET_ROWS) {
        // One gather pass per bucket: pack the bucket's value rows.
        packed.clear();
        for &j in bucket.iter() {
            let j = j as usize;
            packed.extend_from_slice(&values[j * d..(j + 1) * d]);
        }
        let hi = *bucket.last().expect("chunks are non-empty");
        for rw in 0..rows {
            let end = row_ptr[rw + 1];
            let mut c = cursor[rw];
            if inv[rw] == 0.0 {
                // Degenerate normalizer: leave the zero row, but keep
                // the cursor in step with the bucket sweep.
                while c < end && idx[c] <= hi {
                    c += 1;
                }
                cursor[rw] = c;
                continue;
            }
            let orow = &mut out[rw * d..(rw + 1) * d];
            let scale = inv[rw];
            // Both the row's indices and the bucket are ascending, so the
            // bucket position advances monotonically: search only the
            // remaining suffix (O(1) amortized for dense rows, log for
            // sparse ones) instead of bisecting the whole bucket per hit.
            let mut bp = 0usize;
            while c < end && idx[c] <= hi {
                let a = w[c];
                if a != 0.0 {
                    let pos = bp
                        + bucket[bp..]
                            .binary_search(&idx[c])
                            .expect("every fired index is in the union");
                    simd::axpy(orow, &packed[pos * d..(pos + 1) * d], a * scale);
                    bp = pos + 1;
                }
                c += 1;
            }
            cursor[rw] = c;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::softmax::softmax_attention;
    use crate::attention::linf;
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    /// The session's ReLU path is exact vs the dense evaluation (the
    /// paper's "no error for ReLU" claim) through plan→execute.
    #[test]
    fn session_relu_matches_dense() {
        let mut rng = Rng::new(301);
        let inst = AttentionInstance::gaussian(&mut rng, 24, 400, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        for backend in [HsrBackend::Brute, HsrBackend::BallTree, HsrBackend::Projected] {
            let mut session = AttentionConfig::new(
                AttentionKind::Relu { alpha: 2, bias },
                backend,
            )
            .with_bias(bias)
            .build(&inst.k, inst.d);
            let mut out = vec![0f32; inst.m * inst.d];
            let mut fired = vec![0usize; inst.m];
            session.run(&inst.q, &inst.v, &mut out, &mut fired);
            let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 2, bias);
            assert!(linf(&out, &want) < 1e-4, "backend={backend:?}");
            assert!(fired.iter().sum::<usize>() > 0);
        }
    }

    /// Telemetry accessors are guarded on a fresh session and populate
    /// after a run.
    #[test]
    fn telemetry_guarded_and_populates() {
        let mut rng = Rng::new(307);
        let inst = AttentionInstance::gaussian(&mut rng, 16, 300, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let mut session = AttentionConfig::new(
            AttentionKind::Relu { alpha: 2, bias },
            HsrBackend::BallTree,
        )
        .with_bias(bias)
        .build(&inst.k, inst.d);
        // Before any query: ratios are defined (no NaN), counters zero.
        assert_eq!(session.bulk_report_fraction(), 0.0);
        let js = session.telemetry_json();
        assert_eq!(js.req_usize("reported").unwrap(), 0);
        let mut out = vec![0f32; inst.m * inst.d];
        let mut fired = vec![0usize; inst.m];
        session.run(&inst.q, &inst.v, &mut out, &mut fired);
        let js = session.telemetry_json();
        let work = js.req_usize("points_scanned").unwrap()
            + js.req_usize("nodes_visited").unwrap();
        assert!(work > 0);
        let frac = js.req_f64("bulk_report_fraction").unwrap();
        assert!((0.0..=1.0).contains(&frac), "frac={frac}");
    }

    /// plan() + execute() is the same computation run() performs —
    /// bit-identically — and both are stable across thread counts.
    #[test]
    fn plan_execute_equals_run_bitwise() {
        let mut rng = Rng::new(302);
        let inst = AttentionInstance::gaussian(&mut rng, 37, 500, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let cases = [
            AttentionConfig::new(AttentionKind::Relu { alpha: 1, bias }, HsrBackend::BallTree)
                .with_bias(bias),
            AttentionConfig::new(AttentionKind::Softmax, HsrBackend::BallTree)
                .with_bias(0.0)
                .with_top_r(24)
                .with_adaptive(1.0),
            AttentionConfig::new(AttentionKind::Softmax, HsrBackend::Projected).with_bias(bias),
        ];
        for cfg in cases {
            let session = cfg.build(&inst.k, inst.d);
            let mut plan = session.plan(&inst.q);
            let mut via_plan = vec![0f32; inst.m * inst.d];
            session.execute(&mut plan, &inst.v, &mut via_plan);
            for threads in [1usize, 2, 3] {
                let mut s2 = cfg.with_threads(threads).build(&inst.k, inst.d);
                let mut out = vec![0f32; inst.m * inst.d];
                let mut fired = vec![0usize; inst.m];
                s2.run(&inst.q, &inst.v, &mut out, &mut fired);
                assert_eq!(via_plan, out, "threads={threads} cfg={cfg:?}");
                assert_eq!(plan.fired, fired, "threads={threads}");
                assert_eq!(plan.stats, s2.stats, "threads={threads}");
            }
        }
    }

    /// Appending keys (auto-regressive growth) stays consistent with a
    /// from-scratch session, for both attention kinds — the multi-query
    /// block path over a dynamic index with live tail and buckets.
    #[test]
    fn append_matches_fresh_session_both_kinds() {
        let mut rng = Rng::new(303);
        let d = 8;
        let inst = AttentionInstance::gaussian(&mut rng, 9, 300, d);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let kinds = [
            AttentionKind::Relu { alpha: 2, bias },
            AttentionKind::Softmax,
        ];
        for kind in kinds {
            let cfg = AttentionConfig::new(kind, HsrBackend::BallTree).with_bias(bias);
            let mut grown = cfg.build(&inst.k[..150 * d], d);
            for j in 150..300 {
                grown.append_key(&inst.k[j * d..(j + 1) * d]);
            }
            let mut fresh = cfg.build(&inst.k, d);
            let mut out_a = vec![0f32; inst.m * d];
            let mut out_b = vec![0f32; inst.m * d];
            let mut fired_a = vec![0usize; inst.m];
            let mut fired_b = vec![0usize; inst.m];
            grown.run(&inst.q, &inst.v, &mut out_a, &mut fired_a);
            fresh.run(&inst.q, &inst.v, &mut out_b, &mut fired_b);
            assert!(linf(&out_a, &out_b) < 1e-5, "kind={kind:?}");
            assert_eq!(fired_a, fired_b, "kind={kind:?}");
        }
    }

    /// The Lemma threshold policy resolves to the same bias the prefill
    /// path historically used, and softmax over the full report matches
    /// dense softmax when the threshold reports everything.
    #[test]
    fn lemma_policy_and_full_report_softmax() {
        let mut rng = Rng::new(304);
        let inst = AttentionInstance::gaussian(&mut rng, 8, 200, 8);
        let session = AttentionConfig::new(AttentionKind::Softmax, HsrBackend::BallTree)
            .with_bias(f32::NEG_INFINITY)
            .build(&inst.k, inst.d);
        let mut plan = session.plan(&inst.q);
        let mut out = vec![0f32; inst.m * inst.d];
        session.execute(&mut plan, &inst.v, &mut out);
        let dense = softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        assert!(linf(&out, &dense) < 1e-4, "err={}", linf(&out, &dense));
        // Lemma resolution sanity: positive, finite, grows with ln n.
        let s1 = AttentionConfig::new(AttentionKind::Softmax, HsrBackend::Brute)
            .build(&inst.k, inst.d);
        let b1 = s1.bias;
        assert!(b1.is_finite() && b1 > 0.0);
        assert!(
            (b1 as f64 - (0.4 * (inst.n as f64).ln()).sqrt()).abs() < 1e-6,
            "lemma bias {b1}"
        );
    }
}
