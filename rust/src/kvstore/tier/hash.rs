//! Content hashing for segment payload dedup.
//!
//! A published segment is identified by the FNV-1a 64 digest of its
//! token run, its chain-global start position, its (layers, heads,
//! d_head) shape, and the raw **bit patterns** of every K/V row it
//! would freeze. Two publishes with equal digests are only merged
//! after a full bitwise payload comparison ([`super::super::pool`]),
//! so a 64-bit collision can cost a missed dedup, never a wrong share.
//!
//! Hashing bit patterns (not float values) keeps the key aligned with
//! the store's bit-identity contract: `-0.0` and `0.0` are different
//! payloads, equal NaN payloads are the same payload.

use crate::model::kv::KvState;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64.
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Fnv64(FNV_OFFSET)
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64::default()
    }

    #[inline]
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u64::from(b);
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// Digest of the segment a `create_segment(tokens, start, source,
/// src_offset)` call would freeze: rows `[src_offset, src_offset+len)`
/// of every (layer, head) in `source`, plus the token run, start and
/// shape. Computed *before* snapshotting so a dedup hit costs one hash
/// pass and zero allocation.
pub fn segment_content_key(
    tokens: &[u32],
    start: usize,
    source: &KvState,
    src_offset: usize,
) -> u64 {
    let len = tokens.len();
    let d = source.d_head;
    let mut h = Fnv64::new();
    h.write_u64(start as u64);
    h.write_u64(len as u64);
    h.write_u64(source.n_layers as u64);
    h.write_u64(source.n_heads as u64);
    h.write_u64(d as u64);
    for &t in tokens {
        h.write_u32(t);
    }
    for head in &source.heads {
        for f in &head.keys[src_offset * d..(src_offset + len) * d] {
            h.write_u32(f.to_bits());
        }
        for f in &head.values[src_offset * d..(src_offset + len) * d] {
            h.write_u32(f.to_bits());
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::HsrBackend;
    use crate::util::rng::Rng;

    fn filled(seed: u64, n: usize, d: usize) -> KvState {
        let mut rng = Rng::new(seed);
        let mut kv = KvState::new(1, 2, d, Some(HsrBackend::Brute));
        for _ in 0..n {
            for h in 0..2 {
                let k = rng.gaussian_vec_f32(d, 1.0);
                let v = rng.gaussian_vec_f32(d, 1.0);
                kv.head_mut(0, h).append(&k, &v);
            }
        }
        kv
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        let mut h = Fnv64::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h2 = Fnv64::new();
        h2.write(b"foobar");
        assert_eq!(h2.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn key_is_deterministic_and_separates_inputs() {
        let kv = filled(7, 32, 4);
        let kv_same = filled(7, 32, 4);
        let kv_diff = filled(8, 32, 4);
        let tokens: Vec<u32> = (0..16).collect();
        let k0 = segment_content_key(&tokens, 0, &kv, 0);
        assert_eq!(k0, segment_content_key(&tokens, 0, &kv_same, 0));
        // Different rows, offset, start, or tokens all change the key.
        assert_ne!(k0, segment_content_key(&tokens, 0, &kv_diff, 0));
        assert_ne!(k0, segment_content_key(&tokens, 0, &kv, 8));
        assert_ne!(k0, segment_content_key(&tokens, 16, &kv, 0));
        let mut other = tokens.clone();
        other[3] = 999;
        assert_ne!(k0, segment_content_key(&other, 0, &kv, 0));
    }

    #[test]
    fn key_sees_bit_patterns_not_float_equality() {
        let mut a = KvState::new(1, 1, 1, None);
        let mut b = KvState::new(1, 1, 1, None);
        a.head_mut(0, 0).append(&[0.0], &[1.0]);
        b.head_mut(0, 0).append(&[-0.0], &[1.0]);
        let t = [5u32];
        assert_ne!(
            segment_content_key(&t, 0, &a, 0),
            segment_content_key(&t, 0, &b, 0),
            "-0.0 and 0.0 are different payloads"
        );
    }
}
