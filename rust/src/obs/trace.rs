//! Flight recorder: a bounded per-worker ring buffer of timestamped
//! span events, correlated by request id on the shared monotonic
//! engine clock ([`super::clock`]).
//!
//! The recorder is built for postmortems, not for sampling profilers:
//! recording one event is a timestamp read plus a ring-slot write (no
//! allocation, no lock, no I/O), cheap enough to stay on in production.
//! Three consumers drain it:
//!
//! * **Panic dumps** — when a worker panics, the supervisor dumps the
//!   dead engine's ring as JSONL (`panic_worker<W>.jsonl` under the
//!   trace dir, or stderr when none is configured) before discarding
//!   the engine, so the last `ring_capacity` events leading up to the
//!   fault survive it.
//! * **Per-request timelines** — with a trace dir configured, each
//!   request's events are filtered out of the ring at its terminal
//!   outcome and written to `req_<id>.jsonl` continuously.
//! * **Tests/tools** — [`FlightRecorder::events`] returns the ring
//!   oldest-first for in-process inspection.

use super::clock;
use crate::util::json::Json;
use std::io::Write;
use std::path::{Path, PathBuf};

/// What a trace event marks. Engine-wide events (decode steps, HSR
/// traversal totals, tier activity) carry request id 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// A request was admitted to the running set; `a` = prompt tokens,
    /// `b` = tokens adopted from the shared-prefix cache.
    Admit,
    /// Time the request spent queued before admission; `a` = wait in
    /// microseconds, `b` = waiting-queue depth at admission.
    QueueWait,
    /// One chunk of prompt prefill; `a` = chunk tokens, `b` = prompt
    /// tokens still pending after the chunk.
    PrefillChunk,
    /// One batched decode step (engine-wide); `a` = rows decoded,
    /// `b` = step wall time in microseconds.
    DecodeStep,
    /// HSR traversal work of one step (engine-wide); `a` = entries
    /// attended, `b` = dense-equivalent entries.
    HsrTraversal,
    /// Segments demoted to the cold tier (engine-wide); `a` = segments,
    /// `b` = cumulative spill bytes.
    Spill,
    /// Cold segments promoted back (engine-wide); `a` = segments,
    /// `b` = cumulative refaults.
    Refault,
    /// One token accepted into a stream sink; `a` = sibling index,
    /// `b` = the token.
    StreamSend,
    /// Terminal outcome; `a` = generated tokens, `b` = 0 for a clean
    /// finish, 1 otherwise.
    Outcome,
}

impl SpanKind {
    /// Stable wire name (the `span` field of dumped JSONL lines).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admit => "admit",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::PrefillChunk => "prefill_chunk",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::HsrTraversal => "hsr_traversal",
            SpanKind::Spill => "spill",
            SpanKind::Refault => "refault",
            SpanKind::StreamSend => "stream_send",
            SpanKind::Outcome => "outcome",
        }
    }
}

/// One timestamped span event. `a`/`b` are two span-kind-specific
/// payload words (see [`SpanKind`]) — fixed-width so recording never
/// allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Microseconds on the shared monotonic engine clock.
    pub ts_us: u64,
    /// Correlating request id (0 for engine-wide events).
    pub req: u64,
    pub kind: SpanKind,
    pub a: u64,
    pub b: u64,
}

impl TraceEvent {
    /// JSON object form (`{"ts_us":..,"req":..,"span":..,"a":..,"b":..}`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("ts_us", self.ts_us.into())
            .set("req", self.req.into())
            .set("span", self.kind.name().into())
            .set("a", self.a.into())
            .set("b", self.b.into());
        o
    }
}

/// Flight-recorder knobs, carried on
/// [`EngineConfig`](crate::engine::EngineConfig).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Record span events at all. On by default — the `BENCH_obs.json`
    /// section holds steady decode within 3% of tracing off.
    pub enabled: bool,
    /// Ring size in events; the ring keeps the newest `ring_capacity`
    /// events and overwrites the oldest beyond it.
    pub ring_capacity: usize,
    /// Directory for continuous per-request timelines
    /// (`req_<id>.jsonl`) and panic dumps (`panic_worker<W>.jsonl`).
    /// `None` keeps tracing in-memory only (panic dumps then go to
    /// stderr).
    pub trace_dir: Option<PathBuf>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { enabled: true, ring_capacity: 4096, trace_dir: None }
    }
}

/// Bounded ring of [`TraceEvent`]s (see module docs).
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: bool,
    trace_dir: Option<PathBuf>,
    ring: Vec<TraceEvent>,
    cap: usize,
    /// Next slot to overwrite once the ring is full.
    next: usize,
    /// Total events ever recorded (≥ `ring.len()`; the difference is
    /// how many the ring has already forgotten).
    recorded: u64,
}

impl FlightRecorder {
    pub fn new(cfg: &TraceConfig) -> FlightRecorder {
        let cap = cfg.ring_capacity.max(1);
        FlightRecorder {
            enabled: cfg.enabled,
            trace_dir: cfg.trace_dir.clone(),
            ring: Vec::with_capacity(if cfg.enabled { cap.min(1024) } else { 0 }),
            cap,
            next: 0,
            recorded: 0,
        }
    }

    /// A recorder that drops everything (tracing off).
    pub fn disabled() -> FlightRecorder {
        FlightRecorder::new(&TraceConfig {
            enabled: false,
            ring_capacity: 1,
            trace_dir: None,
        })
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Where per-request timelines and panic dumps go, if anywhere.
    pub fn trace_dir(&self) -> Option<&Path> {
        self.trace_dir.as_deref()
    }

    /// Record one span event: a clock read and a ring write. No-op when
    /// tracing is off.
    #[inline]
    pub fn record(&mut self, req: u64, kind: SpanKind, a: u64, b: u64) {
        if !self.enabled {
            return;
        }
        let ev = TraceEvent { ts_us: clock::now_us(), req, kind, a, b };
        if self.ring.len() < self.cap {
            self.ring.push(ev);
        } else {
            self.ring[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
        }
        self.recorded += 1;
    }

    /// Events currently held (≤ ring capacity).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Total events ever recorded, including those the ring forgot.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// The ring's events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.ring.len());
        if self.ring.len() == self.cap {
            out.extend_from_slice(&self.ring[self.next..]);
            out.extend_from_slice(&self.ring[..self.next]);
        } else {
            out.extend_from_slice(&self.ring);
        }
        out
    }

    /// Write the ring (oldest first) as JSONL; returns lines written.
    pub fn dump_jsonl<W: Write>(&self, w: &mut W) -> std::io::Result<usize> {
        let events = self.events();
        for ev in &events {
            writeln!(w, "{}", ev.to_json())?;
        }
        Ok(events.len())
    }

    /// Postmortem dump after a worker panic: the whole ring as JSONL to
    /// `<trace_dir>/panic_worker<widx>.jsonl` (appending, so repeated
    /// panics of one worker accumulate), or to stderr when no trace dir
    /// is configured. Returns the file path when one was written.
    /// Never panics — supervision calls this on the salvage path.
    pub fn dump_panic(&self, widx: usize) -> Option<PathBuf> {
        if !self.enabled || self.ring.is_empty() {
            return None;
        }
        if let Some(dir) = &self.trace_dir {
            let path = dir.join(format!("panic_worker{widx}.jsonl"));
            let file = std::fs::create_dir_all(dir)
                .and_then(|_| {
                    std::fs::OpenOptions::new().create(true).append(true).open(&path)
                });
            if let Ok(mut f) = file {
                if self.dump_jsonl(&mut f).is_ok() {
                    return Some(path);
                }
            }
            return None;
        }
        let mut err = std::io::stderr().lock();
        for ev in self.events() {
            let _ = writeln!(err, "trace worker={widx} {}", ev.to_json());
        }
        None
    }

    /// Continuous per-request timeline: filter this request's events
    /// out of the ring and write them to `<trace_dir>/req_<id>.jsonl`.
    /// No-op without a trace dir. Called at the request's terminal
    /// outcome, when its whole timeline is in the ring (or the oldest
    /// spans have aged out, in which case the tail still lands).
    pub fn dump_request(&self, req: u64) -> Option<PathBuf> {
        let dir = self.trace_dir.as_ref()?;
        if !self.enabled {
            return None;
        }
        let events: Vec<TraceEvent> =
            self.events().into_iter().filter(|e| e.req == req).collect();
        if events.is_empty() {
            return None;
        }
        let path = dir.join(format!("req_{req}.jsonl"));
        std::fs::create_dir_all(dir).ok()?;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .ok()?;
        for ev in &events {
            writeln!(f, "{}", ev.to_json()).ok()?;
        }
        Some(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_newest_in_order() {
        let mut r = FlightRecorder::new(&TraceConfig {
            enabled: true,
            ring_capacity: 4,
            trace_dir: None,
        });
        for i in 0..10u64 {
            r.record(i, SpanKind::DecodeStep, i, 0);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.recorded(), 10);
        let evs = r.events();
        let reqs: Vec<u64> = evs.iter().map(|e| e.req).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9]);
        // Timestamps are non-decreasing on the shared clock.
        assert!(evs.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut r = FlightRecorder::disabled();
        r.record(1, SpanKind::Admit, 2, 3);
        assert!(r.is_empty());
        assert_eq!(r.recorded(), 0);
        assert!(r.dump_panic(0).is_none());
        assert!(r.dump_request(1).is_none());
    }

    #[test]
    fn jsonl_lines_parse_back() {
        let mut r = FlightRecorder::new(&TraceConfig::default());
        r.record(7, SpanKind::Admit, 40, 16);
        r.record(7, SpanKind::Outcome, 8, 0);
        let mut buf = Vec::new();
        assert_eq!(r.dump_jsonl(&mut buf).unwrap(), 2);
        let text = String::from_utf8(buf).unwrap();
        for line in text.lines() {
            let v = Json::parse(line).unwrap();
            assert_eq!(v.req_usize("req").unwrap(), 7);
            assert!(v.req_usize("ts_us").is_ok());
            assert!(matches!(
                v.req_str("span").unwrap(),
                "admit" | "outcome"
            ));
        }
    }

    #[test]
    fn panic_and_request_dumps_write_files() {
        let dir = std::env::temp_dir().join(format!(
            "hsr_trace_test_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut r = FlightRecorder::new(&TraceConfig {
            enabled: true,
            ring_capacity: 64,
            trace_dir: Some(dir.clone()),
        });
        r.record(3, SpanKind::Admit, 10, 0);
        r.record(0, SpanKind::DecodeStep, 1, 5);
        r.record(3, SpanKind::Outcome, 2, 0);
        let p = r.dump_panic(1).expect("panic dump path");
        assert!(std::fs::metadata(&p).unwrap().len() > 0);
        let q = r.dump_request(3).expect("request dump path");
        let body = std::fs::read_to_string(&q).unwrap();
        assert_eq!(body.lines().count(), 2, "only request 3's events");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
