//! END-TO-END SERVING DRIVER (the deliverable-(b) mandated example).
//!
//! Loads the build-time-trained char-LM from `artifacts/`, replays a
//! Poisson serving trace through the multi-worker router — prefill +
//! continuous-batched decode with per-(layer,head) dynamic HSR indices —
//! and reports latency/throughput for the dense baseline vs the
//! HSR-sparse top-r policy (Algorithm 1 inside a real serving loop).
//!
//! Run:  make artifacts && cargo run --release --example serve_demo
//! Args: --model small --requests 32 --workers 2 --gen 48 --rate 8
//!       --policy both|dense|sparse
//!       --affinity on|off   prefix-affinity routing for the trace replay
//!       --send-buffer N     per-stream token buffer (slow consumers shed)
//!       --stream            append a live per-token streaming demo over TCP
//!                           (ends with a {"cmd":"stats"} metrics scrape)
//!
//! Always ends with the tiered-KV showcase: a hot cap far below the
//! working set forces the cached prefix out, the cold tier demotes it
//! (compressed spill) instead of destroying it, and resubmitting the
//! prompt refaults it instead of re-prefilling.

use hsr_attn::engine::serving::Engine;
use hsr_attn::engine::{EngineConfig, GenerationParams, Router, RouterConfig};
use hsr_attn::kvstore::{PrefixCacheMode, SpillConfig};
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::server::{Client, Server, StreamFrame, WireRequest};
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats;
use hsr_attn::workloads::trace::{generate, TraceParams};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Workload shape shared by every section of the demo.
#[derive(Clone, Copy)]
struct DemoOpts {
    workers: usize,
    requests: usize,
    gen_tokens: usize,
    rate: f64,
}

fn run_policy(
    name: &str,
    model: Arc<Model>,
    policy: AttentionPolicy,
    rcfg: RouterConfig,
    opts: DemoOpts,
) {
    let DemoOpts { workers, requests, gen_tokens, rate } = opts;
    let mut rng = Rng::new(7);
    let trace = generate(
        &mut rng,
        &TraceParams {
            rate,
            prompt_log_mean: 4.6, // ~100 tokens
            prompt_log_std: 0.6,
            prompt_min: 16,
            prompt_max: 512,
            mean_new_tokens: gen_tokens as f64,
            max_new_tokens: gen_tokens,
            ..Default::default()
        },
        requests,
    );
    // Prompt content: synthetic corpus-like text bytes.
    let corpus: Vec<u32> = {
        let text = "the merchant carries copper coins by the river. remember: \
                    alder keeps the amber token. a courier guards sealed \
                    letters near the gate. the alder token is amber. ";
        text.bytes().cycle().take(8192).map(|b| b as u32).collect()
    };

    let router = Router::with_config(
        model,
        EngineConfig { policy, ..Default::default() },
        workers,
        rcfg,
    );
    let t0 = Instant::now();
    let mut total_prompt = 0usize;
    let mut rejected = 0usize;
    for req in &trace {
        // Honour arrival times (compressed 4x for demo runtime).
        let due = req.arrival_s / 4.0;
        let now = t0.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
        }
        let start = rng.below(corpus.len() - req.prompt_len);
        let accepted = router
            .submit(
                corpus[start..start + req.prompt_len].to_vec(),
                GenerationParams {
                    max_new_tokens: req.max_new_tokens,
                    temperature: 0.0,
                    stop_token: None,
                    deadline: None,
                },
            )
            .is_ok();
        if accepted {
            total_prompt += req.prompt_len;
        } else {
            rejected += 1;
        }
    }
    router.wait_idle();
    let wall = t0.elapsed().as_secs_f64();
    let responses = router.take_responses();
    let metrics = router.shutdown();
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let ttfts: Vec<f64> = responses.iter().map(|r| r.ttft_ms).collect();
    let gen_total: usize = responses.iter().map(|r| r.tokens.len()).sum();

    println!("\n--- policy = {name} ({workers} workers, {requests} requests) ---");
    if rejected > 0 {
        println!("admission control shed {rejected} requests (default caps)");
    }
    println!(
        "completed {} / {}  in {wall:.2}s   throughput: {:.1} gen tok/s ({:.1} total tok/s)",
        responses.len(),
        requests,
        gen_total as f64 / wall,
        (gen_total + total_prompt) as f64 / wall,
    );
    println!(
        "request latency ms: p50 {:.1}  p90 {:.1}  p99 {:.1}   ttft p50 {:.1}",
        stats::percentile(&latencies, 50.0),
        stats::percentile(&latencies, 90.0),
        stats::percentile(&latencies, 99.0),
        stats::percentile(&ttfts, 50.0),
    );
    println!("engine metrics:\n{}", metrics.summary());
}

/// Live per-token streaming over the real TCP wire protocol: one
/// request with `"stream": true`, token frames printed as they arrive,
/// and the terminal frame's accounting echoed at the end.
fn run_streaming(model: Arc<Model>, rcfg: RouterConfig, opts: DemoOpts) {
    println!("\n--- streaming demo (per-token frames over TCP) ---");
    let router = Arc::new(Router::with_config(
        model,
        EngineConfig { policy: AttentionPolicy::TopR(RSpec::paper()), ..Default::default() },
        opts.workers,
        rcfg,
    ));
    let server = Server::bind(router.clone(), "127.0.0.1:0").expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());

    let mut client = Client::connect(&addr).expect("connect");
    let t0 = Instant::now();
    let frames = client
        .stream_generate(&WireRequest {
            prompt: "the merchant carries ".to_string(),
            max_new_tokens: opts.gen_tokens,
            temperature: 0.0,
            stop_token: None,
            deadline_ms: Some(30_000),
            stream: true,
        })
        .expect("stream_generate");
    let mut first_ms = None;
    let mut text = String::new();
    for frame in &frames {
        match frame {
            StreamFrame::Token { text: piece, .. } => {
                first_ms.get_or_insert(t0.elapsed().as_secs_f64() * 1e3);
                text.push_str(piece);
            }
            StreamFrame::Done { tokens_streamed, finish, latency_ms, .. } => {
                println!("output: {text}");
                println!(
                    "streamed {tokens_streamed} tokens (finish: {finish}), \
                     wire ttft {:.1} ms, total {latency_ms:.1} ms",
                    first_ms.unwrap_or(0.0),
                );
            }
            StreamFrame::Error { code, message, tokens_streamed, .. } => {
                println!("stream error after {tokens_streamed} tokens: {code}: {message}");
            }
            StreamFrame::Cancelled { reason, tokens_streamed, .. } => {
                println!("stream cancelled after {tokens_streamed} tokens: {reason}");
            }
            StreamFrame::Keepalive { .. } => {}
        }
    }

    // Live metrics scrape over the same connection — the
    // `{"cmd":"stats"}` admin frame any operator tool can send (see
    // README § Observability for the snapshot schema).
    match client.stats() {
        Ok(snap) => {
            let counter = |name: &str| {
                snap.get("counters")
                    .and_then(|c| c.get(name))
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0)
            };
            println!(
                "stats scrape: {:.0} requests completed, {:.0} tokens generated \
                 ({:.0} streamed), fired fraction {:.4}",
                counter("requests_completed"),
                counter("generated_tokens"),
                counter("tokens_streamed"),
                snap.get("fired_fraction_overall")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(1.0),
            );
        }
        Err(e) => println!("stats scrape failed: {e}"),
    }
    if let Ok(text) = client.stats_prometheus() {
        println!("prometheus excerpt:");
        for line in text
            .lines()
            .filter(|l| l.starts_with("hsr_requests_") || l.starts_with("hsr_generated_"))
            .take(4)
        {
            println!("  {line}");
        }
    }

    stop.store(true, Ordering::Relaxed);
    srv.join().expect("server thread").expect("serve");
    let router = Arc::try_unwrap(router).ok().expect("server released router");
    let metrics = router.shutdown();
    println!("engine metrics:\n{}", metrics.summary());
}

/// Tiered-KV showcase: prime the prefix cache, flood it past a tiny hot
/// cap so LRU pressure demotes the primed prefix into the compressed
/// cold tier, then resubmit the original prompt and watch it refault
/// (prefill skipped) instead of re-prefilling.
fn run_tiered_refault(model: Arc<Model>, opts: DemoOpts) {
    println!("\n--- tiered KV demo (forced eviction -> spill -> refault) ---");
    let mut eng = Engine::new(
        model,
        EngineConfig {
            policy: AttentionPolicy::TopR(RSpec::paper()),
            prefix_cache: PrefixCacheMode::default(),
            cache_capacity_tokens: 320, // 20 blocks: ~2 cached prompts
            block_tokens: 16,
            spill: SpillConfig::Memory,
            ..Default::default()
        },
    );
    let corpus: Vec<u32> = "the merchant carries copper coins by the river. remember: \
                            alder keeps the amber token. a courier guards sealed \
                            letters near the gate. the alder token is amber. "
        .bytes()
        .cycle()
        .take(512)
        .map(|b| b as u32)
        .collect();
    let params = GenerationParams {
        max_new_tokens: opts.gen_tokens.min(8),
        temperature: 0.0,
        stop_token: None,
        deadline: None,
    };
    let hot = corpus[..96].to_vec();
    let phases: [(&str, Vec<u32>); 5] = [
        ("prime", hot.clone()),
        ("flood-1", corpus[100..196].to_vec()),
        ("flood-2", corpus[200..296].to_vec()),
        ("flood-3", corpus[300..396].to_vec()),
        ("return", hot),
    ];
    for (tag, prompt) in phases {
        let skip0 = eng.metrics.prefill_tokens_skipped;
        eng.submit(prompt, params);
        eng.run_to_completion();
        let _ = eng.take_finished();
        let s = eng.prefix_store().pool.tier_stats();
        println!(
            "  {tag:<8} prefill tokens skipped {:>3} | segments spilled {} / \
             refaulted {} | {} spill bytes",
            eng.metrics.prefill_tokens_skipped - skip0,
            s.segments_spilled,
            s.segments_refaulted,
            s.spill_bytes,
        );
    }
    let leaked = eng.reclaim_and_count_leaks();
    println!("  teardown: {leaked} kv blocks leaked across both tiers");
}

fn main() {
    let args = Args::from_env();
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }
    let model_name = args.str_or("model", "small");
    let opts = DemoOpts {
        workers: args.usize_or("workers", 2),
        requests: args.usize_or("requests", 24),
        gen_tokens: args.usize_or("gen", 48),
        rate: args.f64_or("rate", 8.0),
    };
    let which = args.str_or("policy", "both").to_string();
    let rcfg = RouterConfig {
        affinity: args.str_or("affinity", "on") != "off",
        stream_buffer: args.usize_or("send-buffer", 256),
        ..Default::default()
    };

    let model = Arc::new(Model::load_named(&dir, model_name).expect("load model"));
    println!(
        "== serve_demo: model '{}' ({} layers, d_model {}, vocab {}) ==",
        model.cfg.name, model.cfg.n_layers, model.cfg.d_model, model.cfg.vocab
    );

    if which == "both" || which == "dense" {
        run_policy(
            "dense (naive O(n) attention)",
            model.clone(),
            AttentionPolicy::Dense,
            rcfg,
            opts,
        );
    }
    if which == "both" || which == "sparse" {
        run_policy(
            "hsr-sparse top-r = n^(4/5) (Algorithm 1)",
            model.clone(),
            AttentionPolicy::TopR(RSpec::paper()),
            rcfg,
            opts,
        );
    }
    if args.flag("stream") {
        run_streaming(model.clone(), rcfg, opts);
    }
    run_tiered_refault(model, opts);
    println!("\n(done — see EXPERIMENTS.md §E2E for recorded numbers)");
}
