//! Convex-layers halfplane reporting — the Part-2 analogue of
//! Corollary 3.1, exact for d = 2.
//!
//! Build: peel convex layers (repeated Andrew monotone chain over the
//! lexicographically pre-sorted points). Query for H = {x : <a,x> >= b}:
//! walk layers outermost-in; on each layer find the vertex maximizing
//! <a, v> by binary search on the (monotone) edge-direction angles of the
//! CCW hull, then collect the contiguous arc of qualifying vertices. Every
//! point of layer i+1 lies inside the hull of layer i, so the first layer
//! whose maximum falls below b terminates the query: total cost
//! O(Σ_{touched layers} (log h_ℓ + k_ℓ)) — the O(log n + k) *shape* of
//! AEM92 Part 2, with O(n log n) build instead of O(n^{⌊d/2⌋}) space.
//!
//! (Chazelle's O(n log n) convex-layers construction exists; we use the
//! simpler O(n · L) peeling, L = number of layers, which is ~n^{2/3} for
//! Gaussian clouds — fine for the n this structure is benchmarked at.)
//!
//! This backend keeps the trait's default (looped) multi-query
//! `query_many_scored_into`: each query's cost is a per-layer binary
//! search plus its own reported arc, with no shared node work for a
//! second query to amortize — `nodes_visited` here counts layers whose
//! extreme vertex depends on the query direction, so a block traversal
//! would re-do exactly the per-query work the loop does.

use super::{HalfSpaceReport, QueryStats};

#[derive(Debug, Clone)]
struct Layer {
    /// CCW hull vertices: (x, y, original index).
    xs: Vec<f32>,
    ys: Vec<f32>,
    ids: Vec<u32>,
    /// Unwrapped edge-direction angles; strictly within one 2π turn.
    angles: Vec<f64>,
}

/// Convex-layers structure over 2-D points.
#[derive(Debug, Clone)]
pub struct ConvexLayers2d {
    layers: Vec<Layer>,
    n: usize,
}

#[inline]
fn cross(ox: f64, oy: f64, ax: f64, ay: f64, bx: f64, by: f64) -> f64 {
    (ax - ox) * (by - oy) - (ay - oy) * (bx - ox)
}

/// Andrew monotone chain over points given *already sorted* lexicographic
/// order. Returns hull as indices into `pts`, CCW, no duplicated endpoint.
fn monotone_chain(pts: &[(f64, f64, u32)]) -> Vec<usize> {
    let n = pts.len();
    if n <= 2 {
        return (0..n).collect();
    }
    let mut hull: Vec<usize> = Vec::with_capacity(2 * n);
    // Lower hull.
    for i in 0..n {
        while hull.len() >= 2 {
            let a = pts[hull[hull.len() - 2]];
            let b = pts[hull[hull.len() - 1]];
            if cross(a.0, a.1, b.0, b.1, pts[i].0, pts[i].1) <= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(i);
    }
    // Upper hull.
    let lower_len = hull.len() + 1;
    for i in (0..n - 1).rev() {
        while hull.len() >= lower_len {
            let a = pts[hull[hull.len() - 2]];
            let b = pts[hull[hull.len() - 1]];
            if cross(a.0, a.1, b.0, b.1, pts[i].0, pts[i].1) <= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(i);
    }
    hull.pop(); // last point == first point
    hull
}

impl ConvexLayers2d {
    /// Build by convex-layer peeling. `points` is row-major (x, y) pairs.
    pub fn build(points: &[f32]) -> ConvexLayers2d {
        assert_eq!(points.len() % 2, 0);
        let n = points.len() / 2;
        let mut pts: Vec<(f64, f64, u32)> = (0..n)
            .map(|i| (points[2 * i] as f64, points[2 * i + 1] as f64, i as u32))
            .collect();
        pts.sort_by(|a, b| {
            a.0.partial_cmp(&b.0)
                .unwrap()
                .then(a.1.partial_cmp(&b.1).unwrap())
        });

        let mut layers = Vec::new();
        let mut alive = pts;
        while !alive.is_empty() {
            let hull = monotone_chain(&alive);
            let mut layer = Layer {
                xs: Vec::with_capacity(hull.len()),
                ys: Vec::with_capacity(hull.len()),
                ids: Vec::with_capacity(hull.len()),
                angles: Vec::new(),
            };
            let mut on_hull = vec![false; alive.len()];
            for &h in &hull {
                on_hull[h] = true;
                layer.xs.push(alive[h].0 as f32);
                layer.ys.push(alive[h].1 as f32);
                layer.ids.push(alive[h].2);
            }
            layer.compute_angles();
            layers.push(layer);
            let mut next = Vec::with_capacity(alive.len() - hull.len());
            for (i, p) in alive.into_iter().enumerate() {
                if !on_hull[i] {
                    next.push(p);
                }
            }
            alive = next;
        }
        ConvexLayers2d { layers, n }
    }

    /// Number of convex layers.
    pub fn depth(&self) -> usize {
        self.layers.len()
    }
}

impl Layer {
    fn len(&self) -> usize {
        self.ids.len()
    }

    /// Unwrapped CCW edge angles for binary-searching the extreme vertex.
    fn compute_angles(&mut self) {
        let h = self.len();
        if h < 3 {
            return;
        }
        let mut angles = Vec::with_capacity(h);
        let mut prev: Option<f64> = None;
        let mut offset = 0.0f64;
        for i in 0..h {
            let j = (i + 1) % h;
            let ex = (self.xs[j] - self.xs[i]) as f64;
            let ey = (self.ys[j] - self.ys[i]) as f64;
            let mut th = ey.atan2(ex) + offset;
            if let Some(p) = prev {
                while th < p {
                    th += 2.0 * std::f64::consts::PI;
                    offset += 2.0 * std::f64::consts::PI;
                }
            }
            prev = Some(th);
            angles.push(th);
        }
        self.angles = angles;
    }

    #[inline]
    fn proj(&self, i: usize, ax: f32, ay: f32) -> f32 {
        self.xs[i] * ax + self.ys[i] * ay
    }

    /// Vertex maximizing <a, v>: binary search on edge angles + a local
    /// hill-climb for exactness under collinearity/rounding.
    fn extreme_vertex(&self, ax: f32, ay: f32, stats: &mut QueryStats) -> usize {
        let h = self.len();
        if h <= 8 || self.angles.len() != h {
            // Small layer (or degenerate): direct scan.
            stats.points_scanned += h;
            let mut best = 0;
            for i in 1..h {
                if self.proj(i, ax, ay) > self.proj(best, ax, ay) {
                    best = i;
                }
            }
            return best;
        }
        // <a, e_i> changes sign from + to − at the extreme vertex; edge i
        // ascends iff its angle is within (φ−π/2, φ+π/2) where φ = angle(a).
        // With unwrapped monotone angles we search the first edge whose
        // angle exceeds φ + π/2 (mod the unwrap offset).
        let phi = (ay as f64).atan2(ax as f64);
        let two_pi = 2.0 * std::f64::consts::PI;
        let base = self.angles[0];
        // Candidate cut values φ + π/2 + 2πk that land within angle range.
        let mut cut = phi + std::f64::consts::FRAC_PI_2;
        while cut < base {
            cut += two_pi;
        }
        while cut - two_pi >= base {
            cut -= two_pi;
        }
        let idx = match self
            .angles
            .binary_search_by(|x| x.partial_cmp(&cut).unwrap())
        {
            Ok(i) => i,
            Err(i) => i,
        };
        let mut best = idx % self.len();
        stats.nodes_visited += 1;
        // Hill-climb to the true max (O(1) expected; guards edge cases).
        loop {
            let fwd = (best + 1) % h;
            let bwd = (best + h - 1) % h;
            let cur = self.proj(best, ax, ay);
            stats.points_scanned += 2;
            if self.proj(fwd, ax, ay) > cur {
                best = fwd;
            } else if self.proj(bwd, ax, ay) > cur {
                best = bwd;
            } else {
                return best;
            }
        }
    }

    /// Report the contiguous arc of vertices with <a,v> >= b around the
    /// extreme vertex, optionally pushing each vertex's projection (= its
    /// raw inner product) to `scores`. Returns the maximum projection.
    fn report(
        &self,
        ax: f32,
        ay: f32,
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Option<&mut Vec<f32>>,
        stats: &mut QueryStats,
    ) -> f32 {
        let h = self.len();
        if h == 0 {
            return f32::NEG_INFINITY;
        }
        let m = self.extreme_vertex(ax, ay, stats);
        let maxp = self.proj(m, ax, ay);
        if maxp < b {
            return maxp;
        }
        out.push(self.ids[m]);
        if let Some(sc) = scores.as_mut() {
            sc.push(maxp);
        }
        stats.reported += 1;
        // Walk forward.
        let mut i = (m + 1) % h;
        while i != m {
            stats.points_scanned += 1;
            let p = self.proj(i, ax, ay);
            if p >= b {
                out.push(self.ids[i]);
                if let Some(sc) = scores.as_mut() {
                    sc.push(p);
                }
                stats.reported += 1;
                i = (i + 1) % h;
            } else {
                break;
            }
        }
        if i == m {
            // Forward walk wrapped the whole hull: everything reported.
            return maxp;
        }
        // Walk backward (stop before re-reporting the forward arc).
        let stop = i;
        let mut j = (m + h - 1) % h;
        while j != m && j != stop {
            stats.points_scanned += 1;
            let p = self.proj(j, ax, ay);
            if p >= b {
                out.push(self.ids[j]);
                if let Some(sc) = scores.as_mut() {
                    sc.push(p);
                }
                stats.reported += 1;
                j = (j + h - 1) % h;
            } else {
                break;
            }
        }
        maxp
    }
}

impl HalfSpaceReport for ConvexLayers2d {
    fn len(&self) -> usize {
        self.n
    }

    fn dim(&self) -> usize {
        2
    }

    fn query_into(&self, a: &[f32], b: f32, out: &mut Vec<u32>, stats: &mut QueryStats) {
        self.query_impl(a, b, out, None, stats);
    }

    fn query_scored_into(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        scores: &mut Vec<f32>,
        stats: &mut QueryStats,
    ) {
        self.query_impl(a, b, out, Some(scores), stats);
    }
}

impl ConvexLayers2d {
    fn query_impl(
        &self,
        a: &[f32],
        b: f32,
        out: &mut Vec<u32>,
        mut scores: Option<&mut Vec<f32>>,
        stats: &mut QueryStats,
    ) {
        assert_eq!(a.len(), 2);
        let (ax, ay) = (a[0], a[1]);
        if ax == 0.0 && ay == 0.0 {
            // Degenerate direction: <a,x> = 0 for all x.
            if 0.0 >= b {
                for layer in &self.layers {
                    out.extend_from_slice(&layer.ids);
                    if let Some(sc) = scores.as_mut() {
                        sc.resize(sc.len() + layer.len(), 0.0);
                    }
                    stats.reported += layer.len();
                }
            }
            return;
        }
        for layer in &self.layers {
            stats.nodes_visited += 1;
            let maxp = layer.report(ax, ay, b, out, &mut scores, stats);
            if maxp < b {
                // Everything deeper is inside this hull → cannot qualify.
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::{gaussian_points, reference_query};
    use crate::util::rng::Rng;

    #[test]
    fn square_hull() {
        // Unit square corners + center.
        let pts = vec![0.0f32, 0.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 0.5, 0.5];
        let cl = ConvexLayers2d::build(&pts);
        assert_eq!(cl.depth(), 2);
        // Halfplane x >= 0.9 → the two right corners.
        assert_eq!(cl.query(&[1.0, 0.0], 0.9), vec![1, 2]);
        // x + y >= 1.9 → top-right corner only.
        assert_eq!(cl.query(&[1.0, 1.0], 1.9), vec![2]);
        // everything.
        assert_eq!(cl.query(&[1.0, 0.0], -1.0), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn matches_reference_random() {
        let mut rng = Rng::new(17);
        for _ in 0..40 {
            let n = rng.range(0, 500);
            let pts = gaussian_points(&mut rng, n, 2, 1.0);
            let cl = ConvexLayers2d::build(&pts);
            for _ in 0..6 {
                let a = rng.gaussian_vec_f32(2, 1.0);
                let b = rng.normal(0.0, 1.0) as f32;
                assert_eq!(cl.query(&a, b), reference_query(&pts, 2, &a, b), "n={n}");
            }
        }
    }

    #[test]
    fn collinear_points() {
        // All on a line: peeling must terminate and queries stay exact.
        let mut pts = Vec::new();
        for i in 0..20 {
            pts.extend_from_slice(&[i as f32, 2.0 * i as f32]);
        }
        let cl = ConvexLayers2d::build(&pts);
        for b in [-5.0f32, 0.0, 10.0, 30.0] {
            assert_eq!(cl.query(&[1.0, 0.0], b), reference_query(&pts, 2, &[1.0, 0.0], b));
        }
    }

    #[test]
    fn duplicates_and_tiny_inputs() {
        for n in [0usize, 1, 2, 3] {
            let pts: Vec<f32> = (0..2 * n).map(|i| (i % 3) as f32).collect();
            let cl = ConvexLayers2d::build(&pts);
            let a = [0.3f32, -0.7];
            assert_eq!(cl.query(&a, 0.0), reference_query(&pts, 2, &a, 0.0));
        }
        let pts = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0];
        let cl = ConvexLayers2d::build(&pts);
        assert_eq!(cl.query(&[1.0, 0.0], 0.5).len(), 3);
    }

    #[test]
    fn zero_direction() {
        let pts = vec![1.0f32, 2.0, -3.0, 4.0];
        let cl = ConvexLayers2d::build(&pts);
        assert_eq!(cl.query(&[0.0, 0.0], 0.0).len(), 2);
        assert_eq!(cl.query(&[0.0, 0.0], 1.0).len(), 0);
    }

    #[test]
    fn early_termination_touches_few_layers() {
        let mut rng = Rng::new(23);
        let n = 20_000;
        let pts = gaussian_points(&mut rng, n, 2, 1.0);
        let cl = ConvexLayers2d::build(&pts);
        // A far-out halfplane: only a handful of outer-layer points.
        let a = [1.0f32, 0.0];
        let mut out = Vec::new();
        let mut stats = QueryStats::default();
        cl.query_into(&a, 3.0, &mut out, &mut stats);
        out.sort_unstable();
        assert_eq!(out, reference_query(&pts, 2, &a, 3.0));
        assert!(
            stats.work() < n / 10,
            "work {} should be far below n={n}",
            stats.work()
        );
        assert!(cl.depth() > 10);
    }
}
