//! Workload generators for tests, examples and benches.
//!
//! * [`gaussian`] — the paper's i.i.d. Gaussian Q/K/V model (the
//!   assumption of Lemma 6.1 and Theorems 4.1/5.1).
//! * [`massive`] — distributions with the massive-activation property of
//!   Definition B.3 (Remark B.4's mixture-of-Gaussians construction).
//! * [`trace`] — serving traces (arrival process, prompt/output length
//!   distributions) for the end-to-end engine benches.

pub mod gaussian;
pub mod massive;
pub mod trace;
