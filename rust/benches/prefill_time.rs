//! Bench/reproduction: **Theorems 5.1 / 5.2** — prompt prefilling time
//! (m = Θ(n)), HSR-sparse vs naive dense, across n.
//!
//! Claim shape: naive is O(n²); Algorithm 2 is
//! O(n^{2−1/⌊d/2⌋} + n^{1+4/5}) — a lower fitted exponent, widening gap.

use hsr_attn::attention::relu::relu_attention;
use hsr_attn::attention::softmax::softmax_attention;
use hsr_attn::attention::AttentionKind;
use hsr_attn::bench::{banner, black_box, Bencher};
use hsr_attn::engine::PromptPrefilling;
use hsr_attn::hsr::HsrBackend;
use hsr_attn::util::cli::Args;
use hsr_attn::util::rng::Rng;
use hsr_attn::util::stats::{fmt_ns, power_fit};
use hsr_attn::workloads::gaussian::AttentionInstance;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1).filter(|a| a != "--bench"));
    banner("prefill_time", "paper Theorems 5.1/5.2 (prefill, m = Θ(n))");
    let bench = Bencher::quick();
    let d = args.usize_or("d", 8);
    let ns = args.usize_list_or("ns", &[1_024, 2_048, 4_096, 8_192]);

    for (label, kind) in [
        ("ReLU^2 (Thm 5.1)", AttentionKind::Relu { alpha: 2, bias: 0.0 }),
        ("Softmax top-r (Thm 5.2)", AttentionKind::Softmax),
    ] {
        println!("\n== {label}, d = {d}, m = n ==");
        println!(
            "{:>7} | {:>11} {:>11} {:>8} | {:>10}",
            "n", "naive", "hsr", "speedup", "fired/row"
        );
        let mut xs = Vec::new();
        let mut dense_t = Vec::new();
        let mut sparse_t = Vec::new();
        for &n in &ns {
            let mut rng = Rng::new(n as u64);
            let inst = AttentionInstance::gaussian(&mut rng, n, n, d);
            let bias = inst.params.practical_bias(n) as f32;
            let kind = match kind {
                AttentionKind::Relu { alpha, .. } => AttentionKind::Relu { alpha, bias },
                s => s,
            };
            let naive = bench.run(&format!("naive/n={n}"), || match kind {
                AttentionKind::Relu { alpha, bias } => {
                    black_box(relu_attention(&inst.q, &inst.k, &inst.v, d, alpha, bias));
                }
                AttentionKind::Softmax => {
                    black_box(softmax_attention(&inst.q, &inst.k, &inst.v, d));
                }
            });
            let mut pp = PromptPrefilling::new(kind, HsrBackend::BallTree);
            pp.bias_override = Some(bias);
            if matches!(kind, AttentionKind::Softmax) {
                pp.top_r = Some((n as f64).powf(0.8) as usize);
                pp.bias_override = Some(hsr_attn::attention::threshold::practical_bias_for_target(
                    &inst.params,
                    n,
                    (n as f64).powf(0.8) * 2.0,
                ) as f32);
            }
            let sparse = bench.run(&format!("hsr/n={n}"), || {
                // Algorithm 2 builds the HSR structure inside INFERENCE —
                // the Part-1 init cost is part of the measured time.
                black_box(pp.inference(&inst.q, &inst.k, &inst.v, n, n, d));
            });
            let res = pp.inference(&inst.q, &inst.k, &inst.v, n, n, d);
            let fired = res.fired.iter().sum::<usize>() / n;
            println!(
                "{:>7} | {:>11} {:>11} {:>7.2}x | {:>10}",
                n,
                fmt_ns(naive.median_ns),
                fmt_ns(sparse.median_ns),
                naive.median_ns / sparse.median_ns,
                fired
            );
            xs.push(n as f64);
            dense_t.push(naive.median_ns);
            sparse_t.push(sparse.median_ns);
        }
        if let (Some((ed, r2d)), Some((es, r2s))) =
            (power_fit(&xs, &dense_t), power_fit(&xs, &sparse_t))
        {
            println!(
                "fitted exponents: naive n^{ed:.2} (r2={r2d:.3})  hsr n^{es:.2} (r2={r2s:.3})"
            );
            println!("paper claim: naive ~n^2.0, Algorithm 2 ~n^1.8 (d small)");
        }
    }
}
