//! Algorithm 2 — Prompt Prefilling.
//!
//! The paper's `PromptPrefilling` data structure: both Q and K vary per
//! call (m = Θ(n)), so the HSR structure is built *inside* INFERENCE with
//! the cheap Part-1 build and queried once per query row:
//!
//! ```text
//! INFERENCE({K_i}, {Q_r}, V, n, m, d):
//!   b ← σ_a √(0.4 log n)
//!   HSR.INIT({K_i}, n, d)                       (O(n log n))
//!   for i in 1..m:  S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                   A_{i,j} ← ReLU^α(…)  or Softmax(…)
//!   return D^{-1} A V
//! ```

use crate::attention::relu::relu_attention_row_sparse;
use crate::attention::softmax::softmax_attention_row_subset;
use crate::attention::threshold::ThresholdParams;
use crate::attention::topk::top_r_of_subset;
use crate::attention::AttentionKind;
use crate::hsr::{build_hsr, HsrBackend, QueryStats};

/// Output of one prefill run.
pub struct PrefillResult {
    /// Attention output, row-major [m, d].
    pub out: Vec<f32>,
    /// Activated entries per query row (the k̃_i of Lemma 6.1).
    pub fired: Vec<usize>,
    /// HSR work counters.
    pub stats: QueryStats,
}

/// Algorithm 2 configuration.
#[derive(Debug, Clone, Copy)]
pub struct PromptPrefilling {
    pub kind: AttentionKind,
    pub backend: HsrBackend,
    /// Softmax: keep only the top-r of each report (Theorem 5.2).
    pub top_r: Option<usize>,
    /// Override the Lemma 6.1 threshold (scaled-score units).
    pub bias_override: Option<f32>,
}

impl PromptPrefilling {
    pub fn new(kind: AttentionKind, backend: HsrBackend) -> PromptPrefilling {
        PromptPrefilling { kind, backend, top_r: None, bias_override: None }
    }

    /// INFERENCE: full attention of Q, K, V (non-causal — the paper's
    /// prompt-prefilling / cross-attention setting).
    pub fn inference(
        &self,
        q: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        m: usize,
        d: usize,
    ) -> PrefillResult {
        assert_eq!(q.len(), m * d);
        assert_eq!(keys.len(), n * d);
        assert_eq!(values.len(), n * d);
        let params = ThresholdParams::standard(d, m.max(1));
        let bias = self
            .bias_override
            .unwrap_or_else(|| params.practical_bias(n.max(2)) as f32);
        // Part-1 build: O(n log n)-shaped.
        let hsr = build_hsr(self.backend, keys, d);
        let b_raw = bias * (d as f32).sqrt();

        let mut out = vec![0f32; m * d];
        let mut fired = Vec::with_capacity(m);
        let mut stats = QueryStats::default();
        let mut fire: Vec<u32> = Vec::new();
        let mut scores_buf: Vec<f32> = Vec::new();
        for i in 0..m {
            let qi = &q[i * d..(i + 1) * d];
            fire.clear();
            hsr.query_into(qi, b_raw, &mut fire, &mut stats);
            let orow = &mut out[i * d..(i + 1) * d];
            match self.kind {
                AttentionKind::Relu { alpha, .. } => {
                    relu_attention_row_sparse(
                        qi, keys, values, d, alpha, bias, &fire, &mut scores_buf, orow,
                    );
                    fired.push(fire.len());
                }
                AttentionKind::Softmax => {
                    // Under-reported threshold: fall back to the full
                    // half-space so top-r is exact (Theorem 5.2).
                    if let Some(r) = self.top_r {
                        if fire.len() < r.min(n) {
                            fire.clear();
                            hsr.query_into(qi, f32::NEG_INFINITY, &mut fire, &mut stats);
                        }
                    }
                    let selected = match self.top_r {
                        Some(r) if r < fire.len() => {
                            let mut raw = Vec::with_capacity(fire.len());
                            for &j in &fire {
                                raw.push(crate::hsr::dot(
                                    qi,
                                    &keys[j as usize * d..(j as usize + 1) * d],
                                ));
                            }
                            top_r_of_subset(&fire, &raw, r)
                        }
                        _ => std::mem::take(&mut fire),
                    };
                    softmax_attention_row_subset(
                        qi, keys, values, d, &selected, &mut scores_buf, orow,
                    );
                    fired.push(selected.len());
                }
            }
        }
        PrefillResult { out, fired, stats }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    #[test]
    fn relu_prefill_matches_dense() {
        let mut rng = Rng::new(111);
        let inst = AttentionInstance::gaussian(&mut rng, 150, 150, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        for backend in [HsrBackend::Brute, HsrBackend::BallTree] {
            let pp = PromptPrefilling {
                kind: AttentionKind::Relu { alpha: 2, bias },
                backend,
                top_r: None,
                bias_override: Some(bias),
            };
            let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
            let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 2, bias);
            assert!(linf(&res.out, &want) < 1e-4, "backend={backend:?}");
            assert_eq!(res.fired.len(), inst.m);
        }
    }

    #[test]
    fn layers2d_backend_for_d2() {
        let mut rng = Rng::new(112);
        let inst = AttentionInstance::gaussian(&mut rng, 60, 200, 2);
        let bias = 0.1f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::Layers2d,
            top_r: None,
            bias_override: Some(bias),
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 1, bias);
        assert!(linf(&res.out, &want) < 1e-4);
    }

    #[test]
    fn softmax_topr_stays_close_to_dense() {
        let mut rng = Rng::new(113);
        let inst = AttentionInstance::gaussian(&mut rng, 100, 400, 8);
        let mut pp = PromptPrefilling::new(AttentionKind::Softmax, HsrBackend::BallTree);
        pp.bias_override = Some(f32::NEG_INFINITY);
        pp.top_r = Some(128);
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let dense = crate::attention::softmax::softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        // 128 of 400 top entries carries most of the exp mass; isotropic
        // Gaussian scores are the *worst* case for top-r truncation (no
        // massive activation), so the tolerance here is loose. The
        // massive-activation sweep in benches/error_topr.rs is the sharp
        // version of this check.
        assert!(linf(&res.out, &dense) < 0.3, "err={}", linf(&res.out, &dense));
        assert!(res.fired.iter().all(|&f| f <= 128));
    }

    #[test]
    fn fired_counts_respect_lemma_bound() {
        let mut rng = Rng::new(114);
        let inst = AttentionInstance::gaussian(&mut rng, 64, 2048, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::BallTree,
            top_r: None,
            bias_override: Some(bias),
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let bound = inst.params.row_bound(inst.n) as usize;
        assert!(res.fired.iter().all(|&f| f <= bound));
        assert!(res.fired.iter().sum::<usize>() > 0);
    }
}
