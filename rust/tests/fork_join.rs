//! Fork/join decode scenarios: COW-forked chains under parallel
//! sampling (`n`/`best_of`), beam search (`beam_width`), and explicit
//! mid-decode forks ([`Engine::fork_request`]).
//!
//! The acceptance bar: a sequence forked at generation depth k must
//! produce **bit-identical** outputs to an independent full decode —
//! across every HSR backend (incl. the no-index ablation), both
//! attention policies, and every decode thread count — because
//! publish-on-fork freezes the exact rows both lineages already attend
//! over. Grouped requests must share the prompt chain physically
//! (private-tail blocks only), emit exactly one ranked multi-choice
//! response, and unwind without leaking a block, spill extent, or
//! chain reference under randomized fork/cancel/preempt churn. Like
//! `tests/prefix_cache.rs`, everything runs on `Model::synthetic` with
//! `d_head <= 8`, where float equality can be asserted exactly.

use hsr_attn::engine::serving::{Engine, EngineConfig};
use hsr_attn::engine::{
    Fault, FaultKind, FaultPlan, FinishReason, GenerationParams, Router,
    SchedulerConfig,
};
use hsr_attn::hsr::HsrBackend;
use hsr_attn::model::transformer::{AttentionPolicy, RSpec};
use hsr_attn::model::Model;
use hsr_attn::server::{Client, Server, StreamFrame, WireRequest};
use hsr_attn::util::rng::Rng;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn prompt_bytes(seed: u32, len: usize) -> Vec<u32> {
    (0..len as u32).map(|i| (i * 11 + seed * 37 + 3) % 256).collect()
}

fn engine(
    model: &Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    threads: usize,
) -> Engine {
    Engine::new(
        Arc::clone(model),
        EngineConfig {
            policy,
            hsr_backend: backend,
            cache_capacity_tokens: 1 << 16,
            block_tokens: 16,
            decode_threads: threads,
            ..Default::default()
        },
    )
}

/// Independent full decode of `prompt` (the fork-free reference).
fn baseline(
    model: &Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    prompt: &[u32],
    gen: usize,
) -> Vec<u32> {
    let mut eng = engine(model, policy, backend, 1);
    eng.submit(
        prompt.to_vec(),
        GenerationParams { max_new_tokens: gen, ..Default::default() },
    );
    eng.run_to_completion();
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1);
    done.pop().unwrap().tokens
}

/// Decode `prompt`, fork at generation depth `k`, run both lineages to
/// completion; returns (parent tokens, child tokens, metrics, leaks).
fn fork_at(
    model: &Arc<Model>,
    policy: AttentionPolicy,
    backend: Option<HsrBackend>,
    threads: usize,
    prompt: &[u32],
    gen: usize,
    k: usize,
) -> (Vec<u32>, Vec<u32>, hsr_attn::engine::metrics::Metrics, usize) {
    let mut eng = engine(model, policy, backend, threads);
    let id = eng.submit(
        prompt.to_vec(),
        GenerationParams { max_new_tokens: gen, ..Default::default() },
    );
    let mut guard = 0;
    while eng.generated_len(id).is_some_and(|g| g < k) {
        eng.step();
        guard += 1;
        assert!(guard < 10_000, "never reached generation depth {k}");
    }
    let child = eng.fork_request(id).expect("a decode-ready sequence must fork");
    assert!(child > id, "child ids extend the engine's id space");
    eng.run_to_completion();
    let mut done = eng.take_finished();
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), 2, "parent and child each land a response");
    assert_eq!((done[0].id, done[1].id), (id, child));
    let metrics = eng.metrics.clone();
    let leaks = eng.reclaim_and_count_leaks();
    (done.remove(0).tokens, done.pop().unwrap().tokens, metrics, leaks)
}

/// The headline property: fork-at-step-k is bit-identical to an
/// independent decode of the same prompt — parent AND child — across
/// HSR backends (incl. the no-index ablation), attention policies, and
/// decode thread counts (1 = serial, 0 = one shard per core).
#[test]
fn fork_at_step_k_bit_identity_all_backends_policies_threads() {
    let model = Arc::new(Model::synthetic(88, 2, 2, 8));
    let prompt = prompt_bytes(7, 48);
    let gen = 10;
    let k = 4;
    let cases: Vec<(AttentionPolicy, Option<HsrBackend>)> = vec![
        (AttentionPolicy::Dense, Some(HsrBackend::BallTree)),
        (AttentionPolicy::Dense, None),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::BallTree)),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::Projected)),
        (AttentionPolicy::TopR(RSpec::paper()), Some(HsrBackend::Brute)),
        (AttentionPolicy::TopR(RSpec::paper()), None),
        (AttentionPolicy::TopR(RSpec::Fixed(24)), Some(HsrBackend::BallTree)),
        (AttentionPolicy::TopR(RSpec::Fixed(24)), Some(HsrBackend::Brute)),
    ];
    for (policy, backend) in cases {
        let reference = baseline(&model, policy, backend, &prompt, gen);
        assert_eq!(reference.len(), gen);
        for threads in [1usize, 0] {
            let ctx = format!("policy={policy:?} backend={backend:?} threads={threads}");
            let (parent, child, m, leaks) =
                fork_at(&model, policy, backend, threads, &prompt, gen, k);
            assert_eq!(parent, reference, "{ctx}: parent diverged after fork");
            assert_eq!(child, reference, "{ctx}: child diverged from lineage");
            assert_eq!(m.sequence_forks, 1, "{ctx}");
            // The 64k-token pool always fits the tail: publish-on-fork,
            // never the recompute fallback — and the child adopts every
            // row computed so far (prompt + k generated).
            assert_eq!(m.fork_recompute_fallbacks, 0, "{ctx}");
            assert!(
                m.fork_shared_tokens >= (prompt.len() + k) as u64,
                "{ctx}: fork must share the full computed chain (shared {})",
                m.fork_shared_tokens
            );
            assert_eq!(leaks, 0, "{ctx}: fork leaked KV blocks");
        }
    }
}

/// Forking is depth-independent: every fork depth from the first token
/// to the second-to-last reproduces the reference decode exactly.
#[test]
fn fork_at_every_depth_matches_reference() {
    let model = Arc::new(Model::synthetic(89, 2, 2, 8));
    let prompt = prompt_bytes(11, 40);
    let gen = 8;
    let policy = AttentionPolicy::TopR(RSpec::paper());
    let backend = Some(HsrBackend::BallTree);
    let reference = baseline(&model, policy, backend, &prompt, gen);
    for k in 1..gen {
        let (parent, child, _, leaks) =
            fork_at(&model, policy, backend, 1, &prompt, gen, k);
        assert_eq!(parent, reference, "k={k}");
        assert_eq!(child, reference, "k={k}");
        assert_eq!(leaks, 0, "k={k}");
    }
}

/// n=16 parallel sampling shares the prompt chain physically: once all
/// siblings are fanned out, the pool holds the published chain once
/// plus sixteen private tails — far below the logical (unshared)
/// footprint — and the request resolves to ONE response with 16
/// distinct-index choices.
#[test]
fn parallel_sampling_n16_allocates_private_tails_only() {
    let model = Arc::new(Model::synthetic(90, 2, 2, 8));
    let mut eng = Engine::new(
        Arc::clone(&model),
        EngineConfig {
            policy: AttentionPolicy::TopR(RSpec::paper()),
            cache_capacity_tokens: 1 << 16,
            block_tokens: 16,
            scheduler: SchedulerConfig { max_batch: 16, ..Default::default() },
            ..Default::default()
        },
    );
    let prompt = prompt_bytes(3, 128);
    let gid = eng.submit(
        prompt.clone(),
        GenerationParams {
            max_new_tokens: 6,
            temperature: 1.0,
            n: 16,
            ..Default::default()
        },
    );
    let mut guard = 0;
    while eng.running_len() < 16 {
        eng.step();
        guard += 1;
        assert!(guard < 10_000, "sampling group never fanned out to 16 siblings");
    }
    eng.step(); // every sibling decodes at least one private-tail row
    let (physical, logical) = eng.kv_bytes();
    assert!(physical > 0 && logical > 0);
    assert!(
        physical * 3 <= logical,
        "siblings must share the prompt chain: physical {physical} vs logical {logical}"
    );
    assert_eq!(eng.metrics.sequence_forks, 15);
    assert!(
        eng.metrics.fork_shared_tokens >= 15 * prompt.len() as u64,
        "each fork must adopt the full prompt chain (shared {})",
        eng.metrics.fork_shared_tokens
    );
    eng.run_to_completion();
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1, "a grouped request emits exactly one response");
    let resp = done.pop().unwrap();
    assert_eq!(resp.id, gid);
    assert_eq!(resp.prompt_len, prompt.len());
    assert_eq!(resp.choices.len(), 16);
    let indices: HashSet<u32> = resp.choices.iter().map(|c| c.index).collect();
    assert_eq!(indices.len(), 16, "sibling indices must be distinct");
    for c in &resp.choices {
        assert_eq!(c.finish, FinishReason::Length);
        assert_eq!(c.tokens.len(), 6);
    }
    assert_eq!(resp.tokens, resp.choices[0].tokens, "flat fields mirror the best choice");
    assert_eq!(eng.metrics.group_requests, 1);
    assert_eq!(eng.reclaim_and_count_leaks(), 0, "sampling group leaked KV blocks");
}

/// Grouped sampling is deterministic: the same seed reproduces every
/// choice — tokens AND cumulative log-probabilities — exactly.
#[test]
fn parallel_sampling_is_seed_deterministic() {
    let model = Arc::new(Model::synthetic(91, 2, 2, 8));
    let run = || {
        let mut eng = engine(
            &model,
            AttentionPolicy::TopR(RSpec::paper()),
            Some(HsrBackend::BallTree),
            1,
        );
        eng.submit(
            prompt_bytes(5, 64),
            GenerationParams {
                max_new_tokens: 8,
                temperature: 1.0,
                n: 6,
                ..Default::default()
            },
        );
        eng.run_to_completion();
        let mut done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(eng.reclaim_and_count_leaks(), 0);
        done.pop().unwrap().choices
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce every choice bit-for-bit");
    assert_eq!(a.len(), 6);
}

/// Width-4 beam search: one response, four ranked hypotheses (cumulative
/// log-probability descending), all sharing the prompt chain.
#[test]
fn beam_search_emits_ranked_choices() {
    let model = Arc::new(Model::synthetic(92, 2, 2, 8));
    let mut eng = engine(
        &model,
        AttentionPolicy::TopR(RSpec::paper()),
        Some(HsrBackend::BallTree),
        1,
    );
    let gid = eng.submit(
        prompt_bytes(9, 64),
        GenerationParams { max_new_tokens: 12, beam_width: 4, ..Default::default() },
    );
    eng.run_to_completion();
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1);
    let resp = done.pop().unwrap();
    assert_eq!(resp.id, gid);
    assert_eq!(resp.choices.len(), 4, "a width-4 beam keeps 4 hypotheses");
    for pair in resp.choices.windows(2) {
        assert!(
            pair[0].logprob >= pair[1].logprob,
            "choices must rank by cumulative log-probability descending"
        );
    }
    for c in &resp.choices {
        assert_eq!(c.finish, FinishReason::Length);
        assert_eq!(c.tokens.len(), 12);
        assert!(c.logprob < 0.0, "a 12-token hypothesis has negative log-probability");
    }
    let indices: HashSet<u32> = resp.choices.iter().map(|c| c.index).collect();
    assert_eq!(indices.len(), 4);
    assert_eq!(eng.metrics.group_requests, 1);
    assert!(eng.metrics.sequence_forks >= 3, "beam must fan out past the primary");
    assert_eq!(eng.reclaim_and_count_leaks(), 0, "beam leaked KV blocks");
}

/// `best_of > n`: six candidates decode, the best two by cumulative
/// log-probability come back.
#[test]
fn best_of_decodes_extra_candidates_returns_n() {
    let model = Arc::new(Model::synthetic(93, 2, 2, 8));
    let mut eng = engine(
        &model,
        AttentionPolicy::TopR(RSpec::paper()),
        Some(HsrBackend::BallTree),
        1,
    );
    eng.submit(
        prompt_bytes(13, 48),
        GenerationParams {
            max_new_tokens: 6,
            temperature: 1.0,
            n: 2,
            best_of: 6,
            ..Default::default()
        },
    );
    eng.run_to_completion();
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1);
    let resp = done.pop().unwrap();
    assert_eq!(resp.choices.len(), 2, "best_of candidates beyond n are dropped");
    assert!(resp.choices[0].logprob >= resp.choices[1].logprob);
    assert_eq!(eng.metrics.sequence_forks, 5, "all six candidates must decode");
    assert_eq!(eng.reclaim_and_count_leaks(), 0);
}

/// Cancelling a grouped request mid-decode fans out to every sibling
/// and still aggregates into exactly one terminal response.
#[test]
fn group_cancel_fans_out_without_leaks() {
    let model = Arc::new(Model::synthetic(94, 2, 2, 8));
    let mut eng = engine(
        &model,
        AttentionPolicy::TopR(RSpec::paper()),
        Some(HsrBackend::BallTree),
        1,
    );
    let gid = eng.submit(
        prompt_bytes(17, 64),
        GenerationParams {
            max_new_tokens: 1_000,
            temperature: 1.0,
            n: 8,
            ..Default::default()
        },
    );
    let mut guard = 0;
    while eng.running_len() < 8 {
        eng.step();
        guard += 1;
        assert!(guard < 10_000, "group never fanned out");
    }
    assert!(eng.cancel(gid), "a live group must be cancellable");
    assert!(!eng.cancel(gid), "double cancel must be a no-op");
    let mut done = eng.take_finished();
    assert_eq!(done.len(), 1, "the cancelled group aggregates into one response");
    let resp = done.pop().unwrap();
    assert_eq!(resp.id, gid);
    assert_eq!(resp.finish, FinishReason::Cancelled);
    assert!(!resp.choices.is_empty());
    assert!(resp.choices.iter().all(|c| c.finish == FinishReason::Cancelled));
    assert_eq!(eng.reclaim_and_count_leaks(), 0, "group cancel leaked KV blocks");
}

/// Randomized fork/join/prune/cancel/preempt churn over plain requests,
/// sampling groups, beams, and explicit mid-decode forks — on a pool
/// small enough to force preemption and the recompute-fork fallback.
/// Every accepted request reaches exactly one terminal response and
/// teardown leaves the ledger exact: zero leaked blocks, zero live
/// spill bytes, zero chain references.
#[test]
fn fork_join_churn_zero_leaks() {
    let model = Arc::new(Model::synthetic(95, 2, 2, 8));
    for seed in [0xf0cc_u64, 0x10ad, 0xbead] {
        let mut eng = Engine::new(
            Arc::clone(&model),
            EngineConfig {
                policy: AttentionPolicy::TopR(RSpec::paper()),
                cache_capacity_tokens: 512,
                block_tokens: 16,
                scheduler: SchedulerConfig {
                    max_batch: 6,
                    prefill_chunk: 16,
                    step_token_budget: 64,
                    ..Default::default()
                },
                seed,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(seed);
        // (id, grouped): grouped forks add a sibling to the group (no
        // extra response); ungrouped forks are full requests.
        let mut known: Vec<(u64, bool)> = Vec::new();
        let mut expected = 0usize;
        for _ in 0..120 {
            match rng.below(10) {
                0..=2 => {
                    let p = prompt_bytes(rng.below(1 << 20) as u32, rng.range(16, 49));
                    let id = eng.submit(
                        p,
                        GenerationParams {
                            max_new_tokens: rng.range(4, 17),
                            ..Default::default()
                        },
                    );
                    known.push((id, false));
                    expected += 1;
                }
                3 => {
                    let p = prompt_bytes(rng.below(1 << 20) as u32, rng.range(16, 49));
                    let id = eng.submit(
                        p,
                        GenerationParams {
                            max_new_tokens: rng.range(4, 13),
                            temperature: 1.0,
                            n: rng.range(2, 5) as u32,
                            ..Default::default()
                        },
                    );
                    known.push((id, true));
                    expected += 1;
                }
                4 => {
                    let p = prompt_bytes(rng.below(1 << 20) as u32, rng.range(16, 49));
                    let id = eng.submit(
                        p,
                        GenerationParams {
                            max_new_tokens: rng.range(4, 13),
                            beam_width: rng.range(2, 5) as u32,
                            ..Default::default()
                        },
                    );
                    known.push((id, true));
                    expected += 1;
                }
                5 if !known.is_empty() => {
                    let (id, grouped) = known[rng.below(known.len())];
                    if let Some(child) = eng.fork_request(id) {
                        if !grouped {
                            known.push((child, false));
                            expected += 1;
                        }
                    }
                }
                6 if !known.is_empty() => {
                    let (id, _) = known[rng.below(known.len())];
                    // A finished id is a no-op false; either way its
                    // response was already counted at submission.
                    let _ = eng.cancel(id);
                }
                _ => {
                    for _ in 0..rng.range(1, 9) {
                        eng.step();
                    }
                }
            }
        }
        eng.run_to_completion();
        let done = eng.take_finished();
        assert_eq!(
            done.len(),
            expected,
            "seed={seed:#x}: every request needs exactly one terminal response"
        );
        let m = eng.metrics.clone();
        assert!(m.group_requests >= 1, "seed={seed:#x}: churn must admit groups");
        assert!(m.sequence_forks >= 1, "seed={seed:#x}: churn must fork");
        assert_eq!(
            eng.reclaim_and_count_leaks(),
            0,
            "seed={seed:#x}: churn leaked KV blocks"
        );
        assert_eq!(
            eng.prefix_store().pool.spill_live_bytes(),
            0,
            "seed={seed:#x}: churn leaked spill extents"
        );
    }
}

// ---------------------------------------------------------------------
// Streaming × fork: per-sibling frames over TCP — clean runs, dropped
// best_of candidates ("pruned"), and a worker kill mid-beam.
// ---------------------------------------------------------------------

/// Per-sibling frame accounting of a grouped stream: token frames per
/// sibling, exactly one terminal per observed sibling, and each
/// terminal's `tokens_streamed` naming that sibling's own count.
/// Returns (tokens per sibling, terminal frames per sibling).
fn tally_grouped(frames: &[StreamFrame]) -> (HashMap<u32, u64>, HashMap<u32, &StreamFrame>) {
    let mut tokens: HashMap<u32, u64> = HashMap::new();
    let mut terminals: HashMap<u32, &StreamFrame> = HashMap::new();
    let mut next_seq = 0u64;
    for f in frames {
        match f {
            StreamFrame::Token { seq, sibling, .. } => {
                assert_eq!(*seq, next_seq, "seq stays globally contiguous");
                next_seq += 1;
                *tokens.entry(*sibling).or_insert(0) += 1;
            }
            StreamFrame::Keepalive { .. } => {}
            StreamFrame::Done { sibling, tokens_streamed, .. }
            | StreamFrame::Error { sibling, tokens_streamed, .. }
            | StreamFrame::Cancelled { sibling, tokens_streamed, .. } => {
                assert!(
                    terminals.insert(*sibling, f).is_none(),
                    "sibling {sibling} got two terminal frames"
                );
                assert_eq!(
                    *tokens_streamed,
                    tokens.get(sibling).copied().unwrap_or(0),
                    "sibling {sibling} terminal must carry its own token count"
                );
            }
        }
    }
    (tokens, terminals)
}

#[test]
fn grouped_stream_delivers_one_terminal_per_sibling() {
    let model = Arc::new(Model::synthetic(96, 2, 2, 8));
    let router = Arc::new(Router::new(model, EngineConfig::default(), 2));
    let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());

    let mut c = Client::connect(&addr).unwrap();
    let frames = c
        .stream_generate(&WireRequest {
            prompt: "stream four parallel samples ".to_string(),
            max_new_tokens: 6,
            temperature: 1.0,
            stream: true,
            n: 4,
            ..Default::default()
        })
        .expect("an unloaded pool must stream");
    let (tokens, terminals) = tally_grouped(&frames);
    assert_eq!(terminals.len(), 4, "one terminal frame per sibling");
    assert_eq!(tokens.values().sum::<u64>(), 4 * 6);
    for (sib, f) in &terminals {
        match f {
            StreamFrame::Done { finish, siblings, .. } => {
                assert_eq!(finish, "length");
                assert_eq!(*siblings, 4, "sibling {sib} must announce the group size");
            }
            other => panic!("sibling {sib}: expected done, got {other:?}"),
        }
    }

    stop.store(true, Ordering::Relaxed);
    srv.join().expect("server thread").expect("serve exits cleanly");
    let router = Arc::try_unwrap(router).ok().expect("router released");
    let m = router.shutdown();
    assert_eq!(m.tokens_streamed, 4 * 6);
    assert_eq!(m.kv_blocks_leaked, 0);
}

/// `best_of > n` over the wire: dropped candidates streamed tokens but
/// have no surviving choice — their streams close with a `pruned`
/// cancelled frame; the winner closes with `done`.
#[test]
fn dropped_best_of_candidates_close_with_pruned_frames() {
    let model = Arc::new(Model::synthetic(97, 2, 2, 8));
    let router = Arc::new(Router::new(model, EngineConfig::default(), 1));
    let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());

    let mut c = Client::connect(&addr).unwrap();
    let frames = c
        .stream_generate(&WireRequest {
            prompt: "three candidates one winner ".to_string(),
            max_new_tokens: 5,
            temperature: 1.0,
            stream: true,
            n: 1,
            best_of: 3,
            ..Default::default()
        })
        .expect("stream");
    let (_, terminals) = tally_grouped(&frames);
    assert_eq!(terminals.len(), 3, "all three candidates streamed");
    let mut done = 0;
    let mut pruned = 0;
    for f in terminals.values() {
        match f {
            StreamFrame::Done { finish, .. } => {
                assert_eq!(finish, "length");
                done += 1;
            }
            StreamFrame::Cancelled { reason, .. } => {
                assert_eq!(reason, "pruned");
                pruned += 1;
            }
            other => panic!("unexpected terminal {other:?}"),
        }
    }
    assert_eq!((done, pruned), (1, 2), "one winner, two dropped candidates");

    stop.store(true, Ordering::Relaxed);
    srv.join().expect("server thread").expect("serve exits cleanly");
    let router = Arc::try_unwrap(router).ok().expect("router released");
    assert_eq!(router.shutdown().kv_blocks_leaked, 0);
}

/// Worker kill mid-beam: the panic lands after every hypothesis has
/// streamed tokens, so each observed sibling must still close with
/// exactly one terminal frame — a `worker_failed` error carrying that
/// sibling's own truncation point.
#[test]
fn worker_kill_mid_beam_closes_every_sibling_stream() {
    let model = Arc::new(Model::synthetic(98, 2, 2, 8));
    let cfg = EngineConfig {
        faults: FaultPlan::none()
            .with(Fault { worker: 0, step: 12, kind: FaultKind::Panic }),
        ..Default::default()
    };
    let router = Arc::new(Router::new(model, cfg, 1));
    let server = Server::bind(router.clone(), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let stop = server.stop_handle();
    let srv = std::thread::spawn(move || server.serve());

    let mut c = Client::connect(&addr).unwrap();
    let frames = c
        .stream_generate(&WireRequest {
            prompt: "beam that dies mid flight ".to_string(),
            max_new_tokens: 64,
            stream: true,
            beam_width: 4,
            ..Default::default()
        })
        .expect("frames arrive up to and including the per-sibling errors");
    let (tokens, terminals) = tally_grouped(&frames);
    assert!(
        terminals.len() >= 2,
        "panic at step 12 lands after the beam fanned out (saw {} siblings)",
        terminals.len()
    );
    assert_eq!(
        terminals.len(),
        tokens.len().max(1),
        "every sibling that streamed gets its own terminal frame"
    );
    for (sib, f) in &terminals {
        match f {
            StreamFrame::Error { code, siblings, .. } => {
                assert_eq!(code, "worker_failed", "sibling {sib}");
                assert_eq!(*siblings, terminals.len() as u32, "sibling {sib}");
            }
            other => panic!("sibling {sib}: expected worker_failed error, got {other:?}"),
        }
    }
    assert!(tokens.values().sum::<u64>() >= 2, "progress must precede the panic");

    // The pool must recover: a fresh request succeeds post-restart.
    let mut ok = false;
    for _ in 0..100 {
        if let Ok(mut probe) = Client::connect(&addr) {
            if let Ok(v) = probe.generate("post recovery probe ", 4) {
                if v.get("finish").is_some() {
                    ok = true;
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(ok, "server unresponsive after the mid-beam worker kill");

    stop.store(true, Ordering::Relaxed);
    srv.join().expect("server thread").expect("serve exits cleanly");
    let router = Arc::try_unwrap(router).ok().expect("router released");
    let m = router.shutdown();
    assert_eq!(m.worker_panics, 1);
    assert_eq!(m.worker_restarts, 1);
    assert_eq!(m.kv_blocks_leaked, 0);
}
