//! Tiered KV: the cold tier behind [`super::PagePool`].
//!
//! Hot segments live in pool blocks as uncompressed f32 payload plus a
//! built per-(segment, head) [`DynamicHsr`]. When LRU pressure demotes
//! an unreferenced segment, its payload is compressed ([`compress`])
//! into a spill arena ([`spill`]) instead of being destroyed; the radix
//! node survives, so a later prompt match *refaults* the segment —
//! decompress, re-reserve blocks, reattach the HSR index — instead of
//! re-prefilling tokens the fleet already paid to compute.
//!
//! [`SpillPolicy`] decides what happens to the per-head index across
//! the cold trip:
//!
//! * [`SpillPolicy::RebuildOnRefault`] — spill the payload only and
//!   rebuild each index from the decompressed keys with
//!   [`DynamicHsr::from_points`]. Smallest cold records. Exact for
//!   segment indices because segments are frozen at publish via
//!   `from_points` (single batch-built bucket, deterministic slot) —
//!   the rebuild reproduces the dropped index bit-for-bit.
//! * [`SpillPolicy::SerializeHsr`] — serialize the index's logarithmic
//!   *structure* (bucket decomposition, insertion ids, brute tail)
//!   alongside the payload and reconstruct it bucket-by-bucket on
//!   refault. Larger cold records, but faithful to insertion-grown
//!   structures too (a future mutable-segment tier), not just
//!   batch-built ones.
//!
//! Both policies produce bit-identical query behavior for today's
//! frozen segments — asserted across four backends in
//! `tests/kv_tiers.rs`; the trade they expose is spill-record size
//! versus structural generality.

pub mod compress;
pub mod hash;
pub mod spill;

pub use spill::{Extent, SpillStore};

use crate::hsr::dynamic::{DynamicHsr, HsrStructure};
use crate::hsr::HsrBackend;
use crate::model::kv::{HeadKv, KvState};
use compress::{compress_f32s, decompress_f32s, get_uvarint, put_uvarint};
use std::path::PathBuf;

/// Where the cold tier lives (the CLI's `--spill <dir|mem|off>`).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum SpillConfig {
    /// No cold tier: LRU eviction destroys segments (pre-tier behavior).
    #[default]
    Off,
    /// In-memory arena — hermetic tests/benches, or "compressed RAM
    /// tier" deployments.
    Memory,
    /// File-backed arena in this directory (one uniquely-named file per
    /// pool; unlinked on drop).
    Dir(PathBuf),
}

impl SpillConfig {
    /// Parse a CLI value. The error lists the valid forms so
    /// `util::cli::Args::parse_or_exit` can surface it verbatim.
    pub fn parse(s: &str) -> Result<SpillConfig, String> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" | "no" | "false" => Ok(SpillConfig::Off),
            "mem" | "memory" => Ok(SpillConfig::Memory),
            other if !other.is_empty() && !other.starts_with('-') => {
                Ok(SpillConfig::Dir(PathBuf::from(s)))
            }
            other => Err(format!(
                "invalid spill target '{other}'; valid values: off|mem|<directory>"
            )),
        }
    }

    pub fn enabled(&self) -> bool {
        !matches!(self, SpillConfig::Off)
    }
}

/// What to do with the per-(segment, head) HSR index when a segment
/// goes cold. See the module docs for the trade-off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SpillPolicy {
    /// Payload-only cold records; rebuild indices from decompressed
    /// keys at refault.
    #[default]
    RebuildOnRefault,
    /// Serialize the index structure alongside the payload; reconstruct
    /// it bucket-by-bucket at refault.
    SerializeHsr,
}

impl SpillPolicy {
    pub fn parse(s: &str) -> Result<SpillPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "rebuild" | "rebuild-on-refault" => Ok(SpillPolicy::RebuildOnRefault),
            "serialize" | "serialize-hsr" => Ok(SpillPolicy::SerializeHsr),
            other => Err(format!(
                "invalid spill policy '{other}'; valid values: rebuild|serialize"
            )),
        }
    }
}

/// Cold-tier configuration handed to [`super::PagePool::with_tier`].
#[derive(Debug, Clone, Default)]
pub struct TierConfig {
    pub spill: SpillConfig,
    pub policy: SpillPolicy,
}

/// Cumulative tier counters, accumulated inside the pool (where the
/// events happen, far from any `&mut Metrics`) and synced onto the
/// engine's metrics once per step.
#[derive(Debug, Clone, Copy, Default)]
pub struct TierStats {
    /// Segments demoted hot → cold.
    pub segments_spilled: u64,
    /// Segments refaulted cold → hot.
    pub segments_refaulted: u64,
    /// Cumulative compressed bytes written to the spill arena.
    pub spill_bytes: u64,
    /// Nanoseconds spent decoding payloads + reattaching HSR indices
    /// during refaults (reported as `refault_rebuild_ms`).
    pub refault_rebuild_ns: u64,
    /// Publishes that resolved to an existing physical segment.
    pub dedup_hits: u64,
    /// Uncompressed payload bytes those hits did not duplicate.
    pub dedup_bytes_saved: u64,
}

impl TierStats {
    /// Fraction of spilled segments that later refaulted (0.0 while
    /// nothing has spilled — guarded like every metrics ratio).
    pub fn refault_rate(&self) -> f64 {
        crate::obs::telemetry::ratio_or(
            self.segments_refaulted as f64,
            self.segments_spilled as f64,
            0.0,
        )
    }

    /// Mean refault rebuild cost in milliseconds (0.0 with no refaults).
    pub fn mean_rebuild_ms(&self) -> f64 {
        crate::obs::telemetry::ratio_or(
            self.refault_rebuild_ns as f64 / 1e6,
            self.segments_refaulted as f64,
            0.0,
        )
    }

    /// JSON form for bench reports and trace dumps.
    pub fn to_json(&self) -> crate::util::json::Json {
        let mut o = crate::util::json::Json::obj();
        o.set("segments_spilled", self.segments_spilled.into())
            .set("segments_refaulted", self.segments_refaulted.into())
            .set("spill_bytes", self.spill_bytes.into())
            .set("refault_rebuild_ms", (self.refault_rebuild_ns as f64 / 1e6).into())
            .set("dedup_hits", self.dedup_hits.into())
            .set("dedup_bytes_saved", self.dedup_bytes_saved.into())
            .set("refault_rate", self.refault_rate().into())
            .set("mean_rebuild_ms", self.mean_rebuild_ms().into());
        o
    }
}

// --- cold-record codec -------------------------------------------------
//
// record := 'K' version=1 flags
//           uv(n_layers) uv(n_heads) uv(d_head) uv(rows)
//           per head: calib{0|1 [f32bits]} keys_block values_block
//           if flags&HAS_HSR: per head: {0|1 hsr_structure}
// hsr_structure := uv(n_slots)
//                  per slot: {0|1 uv(count) ids... points_block}
//                  uv(tail_count) tail_ids... tail_points_block

const RECORD_MAGIC: u8 = b'K';
const RECORD_VERSION: u8 = 1;
const FLAG_HAS_HSR: u8 = 1;

/// Serialize a frozen segment's [`KvState`] into a cold record.
pub(crate) fn encode_segment(kv: &KvState, policy: SpillPolicy, out: &mut Vec<u8>) {
    let serialize_hsr =
        policy == SpillPolicy::SerializeHsr && kv.heads.iter().any(|h| h.hsr.is_some());
    out.push(RECORD_MAGIC);
    out.push(RECORD_VERSION);
    out.push(if serialize_hsr { FLAG_HAS_HSR } else { 0 });
    put_uvarint(out, kv.n_layers as u64);
    put_uvarint(out, kv.n_heads as u64);
    put_uvarint(out, kv.d_head as u64);
    put_uvarint(out, kv.len() as u64);
    for head in &kv.heads {
        match head.calib_threshold {
            Some(c) => {
                out.push(1);
                out.extend_from_slice(&c.to_bits().to_le_bytes());
            }
            None => out.push(0),
        }
        compress_f32s(&head.keys, out);
        compress_f32s(&head.values, out);
    }
    if serialize_hsr {
        for head in &kv.heads {
            match &head.hsr {
                Some(hsr) => {
                    out.push(1);
                    encode_hsr_structure(&hsr.structure(), out);
                }
                None => out.push(0),
            }
        }
    }
}

fn encode_hsr_structure(s: &HsrStructure, out: &mut Vec<u8>) {
    put_uvarint(out, s.slots.len() as u64);
    for slot in &s.slots {
        match slot {
            Some((ids, points)) => {
                out.push(1);
                put_uvarint(out, ids.len() as u64);
                for &id in ids {
                    put_uvarint(out, u64::from(id));
                }
                compress_f32s(points, out);
            }
            None => out.push(0),
        }
    }
    put_uvarint(out, s.tail_ids.len() as u64);
    for &id in &s.tail_ids {
        put_uvarint(out, u64::from(id));
    }
    compress_f32s(&s.tail_points, out);
}

fn get_u8(bytes: &[u8], pos: &mut usize) -> Option<u8> {
    let &b = bytes.get(*pos)?;
    *pos += 1;
    Some(b)
}

/// Sanity cap on decoded counts (heads, ids, slots); a corrupt record
/// must not allocate unbounded memory.
const MAX_COUNT: u64 = 1 << 24;

/// Decode a cold record back into a frozen [`KvState`]. `backend` is
/// the pool's HSR backend: indices are rebuilt from keys when the
/// record is payload-only, reconstructed from the serialized structure
/// otherwise. `None` on any corruption — the caller treats the record
/// as lost and falls back to re-prefill.
pub(crate) fn decode_segment(bytes: &[u8], backend: Option<HsrBackend>) -> Option<KvState> {
    let mut pos = 0usize;
    if get_u8(bytes, &mut pos)? != RECORD_MAGIC || get_u8(bytes, &mut pos)? != RECORD_VERSION {
        return None;
    }
    let flags = get_u8(bytes, &mut pos)?;
    let n_layers = get_uvarint(bytes, &mut pos)?;
    let n_heads = get_uvarint(bytes, &mut pos)?;
    let d_head = get_uvarint(bytes, &mut pos)?;
    let rows = get_uvarint(bytes, &mut pos)?;
    if n_layers == 0
        || n_heads == 0
        || d_head == 0
        || n_layers * n_heads > MAX_COUNT
        || rows > MAX_COUNT
    {
        return None;
    }
    let (n_layers, n_heads, d) = (n_layers as usize, n_heads as usize, d_head as usize);
    let rows = rows as usize;
    let total_heads = n_layers * n_heads;
    let mut parts: Vec<(Vec<f32>, Vec<f32>, Option<f32>)> = Vec::with_capacity(total_heads);
    for _ in 0..total_heads {
        let calib = match get_u8(bytes, &mut pos)? {
            0 => None,
            1 => {
                let raw = bytes.get(pos..pos + 4)?;
                pos += 4;
                Some(f32::from_bits(u32::from_le_bytes(raw.try_into().ok()?)))
            }
            _ => return None,
        };
        let keys = decompress_f32s(bytes, &mut pos)?;
        let values = decompress_f32s(bytes, &mut pos)?;
        if keys.len() != rows * d || values.len() != rows * d {
            return None;
        }
        parts.push((keys, values, calib));
    }
    let mut structures: Vec<Option<HsrStructure>> = Vec::new();
    if flags & FLAG_HAS_HSR != 0 {
        for _ in 0..total_heads {
            structures.push(match get_u8(bytes, &mut pos)? {
                0 => None,
                1 => Some(decode_hsr_structure(bytes, &mut pos, rows, d)?),
                _ => return None,
            });
        }
    }
    let mut heads = Vec::with_capacity(total_heads);
    for (i, (keys, values, calib)) in parts.into_iter().enumerate() {
        let hsr = match structures.get(i).and_then(|s| s.as_ref()) {
            Some(s) => {
                let b = backend?; // structure recorded but pool has no backend: corrupt
                Some(DynamicHsr::from_structure(b, d, s))
            }
            None if flags & FLAG_HAS_HSR != 0 => None,
            None => backend.map(|b| DynamicHsr::from_points(b, &keys, d)),
        };
        heads.push(HeadKv::from_frozen_parts(keys, values, hsr, calib, d));
    }
    Some(KvState { heads, n_layers, n_heads, d_head: d })
}

fn decode_hsr_structure(
    bytes: &[u8],
    pos: &mut usize,
    rows: usize,
    d: usize,
) -> Option<HsrStructure> {
    let n_slots = get_uvarint(bytes, pos)?;
    if n_slots > 64 {
        return None;
    }
    let mut slots = Vec::with_capacity(n_slots as usize);
    let mut total = 0usize;
    for _ in 0..n_slots {
        match get_u8(bytes, pos)? {
            0 => slots.push(None),
            1 => {
                let count = get_uvarint(bytes, pos)?;
                if count > MAX_COUNT {
                    return None;
                }
                let mut ids = Vec::with_capacity(count as usize);
                for _ in 0..count {
                    ids.push(u32::try_from(get_uvarint(bytes, pos)?).ok()?);
                }
                let points = decompress_f32s(bytes, pos)?;
                if points.len() != ids.len() * d {
                    return None;
                }
                total += ids.len();
                slots.push(Some((ids, points)));
            }
            _ => return None,
        }
    }
    let tail_count = get_uvarint(bytes, pos)?;
    if tail_count > MAX_COUNT {
        return None;
    }
    let mut tail_ids = Vec::with_capacity(tail_count as usize);
    for _ in 0..tail_count {
        tail_ids.push(u32::try_from(get_uvarint(bytes, pos)?).ok()?);
    }
    let tail_points = decompress_f32s(bytes, pos)?;
    if tail_points.len() != tail_ids.len() * d {
        return None;
    }
    total += tail_ids.len();
    // Every stored row must be indexed exactly once.
    if total != rows {
        return None;
    }
    Some(HsrStructure { slots, tail_ids, tail_points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hsr::{HalfSpaceReport, QueryStats};
    use crate::util::rng::Rng;

    #[test]
    fn tier_stats_ratios_guard_zero_denominators() {
        let empty = TierStats::default();
        assert_eq!(empty.refault_rate(), 0.0);
        assert_eq!(empty.mean_rebuild_ms(), 0.0);
        let js = empty.to_json();
        assert_eq!(js.req_usize("segments_spilled").unwrap(), 0);
        let busy = TierStats {
            segments_spilled: 8,
            segments_refaulted: 2,
            spill_bytes: 4096,
            refault_rebuild_ns: 3_000_000,
            dedup_hits: 1,
            dedup_bytes_saved: 512,
        };
        assert!((busy.refault_rate() - 0.25).abs() < 1e-12);
        assert!((busy.mean_rebuild_ms() - 1.5).abs() < 1e-12);
        let js = busy.to_json();
        assert!((js.req_f64("refault_rate").unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(js.req_usize("dedup_hits").unwrap(), 1);
    }

    fn frozen_kv(seed: u64, rows: usize, d: usize, backend: Option<HsrBackend>) -> KvState {
        let mut rng = Rng::new(seed);
        let mut src = KvState::new(2, 2, d, backend);
        for _ in 0..rows {
            for l in 0..2 {
                for h in 0..2 {
                    let k = rng.gaussian_vec_f32(d, 1.0);
                    let v = rng.gaussian_vec_f32(d, 1.0);
                    src.head_mut(l, h).append(&k, &v);
                }
            }
        }
        src.head_mut(1, 0).calib_threshold = Some(0.42);
        // Frozen exactly the way PagePool freezes segments.
        src.snapshot_range(0, rows, backend)
    }

    fn assert_bit_identical(a: &KvState, b: &KvState, d: usize, seed: u64) {
        assert_eq!(a.heads.len(), b.heads.len());
        let mut rng = Rng::new(seed);
        for (ha, hb) in a.heads.iter().zip(b.heads.iter()) {
            assert_eq!(ha.calib_threshold.map(f32::to_bits), hb.calib_threshold.map(f32::to_bits));
            assert_eq!(
                ha.keys.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                hb.keys.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                ha.values.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                hb.values.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(ha.hsr.is_some(), hb.hsr.is_some());
            for _ in 0..4 {
                let q = rng.gaussian_vec_f32(d, 1.0);
                let thr = rng.normal(0.0, 1.0) as f32;
                let (mut oa, mut sa) = (Vec::new(), Vec::new());
                let (mut ob, mut sb) = (Vec::new(), Vec::new());
                let mut st = QueryStats::default();
                ha.query_scored_into(&q, thr, &mut oa, &mut sa, &mut st);
                hb.query_scored_into(&q, thr, &mut ob, &mut sb, &mut st);
                assert_eq!(oa, ob, "fired sets must match in order");
                assert_eq!(
                    sa.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    sb.iter().map(|f| f.to_bits()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn record_roundtrip_both_policies_all_backends() {
        for backend in [
            Some(HsrBackend::BallTree),
            Some(HsrBackend::Projected),
            Some(HsrBackend::Brute),
            None,
        ] {
            for policy in [SpillPolicy::RebuildOnRefault, SpillPolicy::SerializeHsr] {
                let kv = frozen_kv(50, 33, 8, backend);
                let mut rec = Vec::new();
                encode_segment(&kv, policy, &mut rec);
                let back = decode_segment(&rec, backend)
                    .unwrap_or_else(|| panic!("decodes ({backend:?}, {policy:?})"));
                assert_bit_identical(&kv, &back, 8, 99);
            }
        }
    }

    #[test]
    fn serialize_policy_records_are_larger_payload_identical() {
        let kv = frozen_kv(51, 40, 8, Some(HsrBackend::BallTree));
        let (mut a, mut b) = (Vec::new(), Vec::new());
        encode_segment(&kv, SpillPolicy::RebuildOnRefault, &mut a);
        encode_segment(&kv, SpillPolicy::SerializeHsr, &mut b);
        assert!(b.len() > a.len(), "structure bytes cost record size");
    }

    #[test]
    fn corrupt_records_decode_to_none() {
        let kv = frozen_kv(52, 20, 4, Some(HsrBackend::Brute));
        let mut rec = Vec::new();
        encode_segment(&kv, SpillPolicy::SerializeHsr, &mut rec);
        assert!(decode_segment(&[], Some(HsrBackend::Brute)).is_none());
        for cut in [1usize, 3, rec.len() / 2, rec.len() - 1] {
            assert!(decode_segment(&rec[..cut], Some(HsrBackend::Brute)).is_none());
        }
        let mut bad_magic = rec.clone();
        bad_magic[0] = b'X';
        assert!(decode_segment(&bad_magic, Some(HsrBackend::Brute)).is_none());
        // Structure recorded but no backend available → corrupt, not panic.
        assert!(decode_segment(&rec, None).is_none());
    }

    #[test]
    fn spill_config_parse() {
        assert_eq!(SpillConfig::parse("off"), Ok(SpillConfig::Off));
        assert_eq!(SpillConfig::parse("MEM"), Ok(SpillConfig::Memory));
        assert_eq!(
            SpillConfig::parse("/tmp/spill"),
            Ok(SpillConfig::Dir(PathBuf::from("/tmp/spill")))
        );
        let err = SpillConfig::parse("").unwrap_err();
        assert!(err.contains("off|mem|<directory>"), "{err}");
        assert!(SpillConfig::parse("--oops").is_err());
        assert!(!SpillConfig::Off.enabled());
        assert!(SpillConfig::Memory.enabled());
    }

    #[test]
    fn spill_policy_parse() {
        assert_eq!(SpillPolicy::parse("rebuild"), Ok(SpillPolicy::RebuildOnRefault));
        assert_eq!(SpillPolicy::parse("serialize"), Ok(SpillPolicy::SerializeHsr));
        let err = SpillPolicy::parse("zip").unwrap_err();
        assert!(err.contains("rebuild|serialize"), "{err}");
    }
}
