//! Per-request streaming channel: a bounded, Condvar-signaled token
//! queue between the engine worker that decodes a request and the
//! server connection thread that writes its frames.
//!
//! # Contract
//!
//! * **Producer** (the engine, via [`StreamSink::push_token`]): every
//!   sampled token is offered exactly once, in generation order; each
//!   accepted token gets the next contiguous sequence number. A push
//!   against a full buffer **never blocks and never drops silently** —
//!   it marks the stream *severed* and fails, and the engine sheds the
//!   slow consumer at its next step (the terminal frame then reports
//!   how many tokens made it out). Decode speed is therefore never
//!   coupled to consumer speed, and per-request memory is bounded by
//!   the buffer capacity.
//! * **Terminator** (the router's completion path, via
//!   [`StreamSink::close`]): called exactly once when the request's
//!   terminal [`Outcome`](super::Outcome) is published, after which
//!   [`StreamSink::recv_timeout`] drains the remaining tokens and then
//!   reports [`StreamRecv::Closed`]. Closing is what guarantees the
//!   wire's "exactly one terminal frame per stream" invariant: the
//!   consumer renders its terminal frame on `Closed` and the outcome
//!   table holds exactly one outcome per accepted request.
//! * **Consumer** (the server): [`StreamSink::recv_timeout`] blocks on
//!   the Condvar (no polling) and drains tokens in sequence order. The
//!   wire-visible time-to-first-token is stamped when the first token
//!   *enters* the channel (submission → first token available to the
//!   consumer, so it includes router queueing and prefill but is
//!   independent of when the consumer polls).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One streamed token with its contiguous per-stream sequence number.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamEvent {
    /// 0-based, contiguous: the consumer sees `seq = 0, 1, 2, ...` with
    /// no gaps up to the terminal frame (a full buffer severs the
    /// stream instead of skipping tokens). Grouped requests interleave
    /// siblings on one stream — `seq` stays globally contiguous while
    /// `sibling` says which hypothesis a token belongs to.
    pub seq: u64,
    pub token: u32,
    /// Sibling index of the sequence that produced this token (0 for
    /// plain requests; forked sampling/beam siblings tag their own).
    pub sibling: u32,
}

/// Result of one [`StreamSink::recv_timeout`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamRecv {
    /// The next token in sequence order.
    Event(StreamEvent),
    /// The stream's terminal outcome is published (queue fully
    /// drained); no further events will ever arrive.
    Closed,
    /// Nothing available within the timeout; the stream is still live.
    Empty,
}

#[derive(Debug, Default)]
struct SinkState {
    queue: VecDeque<StreamEvent>,
    /// Tokens accepted so far (== the next sequence number).
    pushed: u64,
    severed: bool,
    closed: bool,
    /// Wire TTFT: set when the first token enters the channel.
    first_token: Option<Duration>,
    /// Same instant on the shared monotonic engine clock (µs), so wire
    /// TTFT merge-sorts with trace events and reqlog lines.
    first_token_ts_us: Option<u64>,
}

/// Bounded per-request streaming channel (see module docs).
#[derive(Debug)]
pub struct StreamSink {
    state: Mutex<SinkState>,
    cv: Condvar,
    cap: usize,
    born: Instant,
}

fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl StreamSink {
    /// A sink buffering at most `cap` undelivered tokens (`cap` is
    /// clamped to ≥ 1 so a stream can always make progress).
    pub fn new(cap: usize) -> StreamSink {
        StreamSink {
            state: Mutex::new(SinkState::default()),
            cv: Condvar::new(),
            cap: cap.max(1),
            born: Instant::now(),
        }
    }

    /// Offer one token. Returns `false` — and permanently severs the
    /// stream — if the consumer has fallen `cap` tokens behind (or the
    /// stream was already severed/closed). Never blocks.
    pub fn push_token(&self, token: u32, sibling: u32) -> bool {
        let mut st = lock_ok(&self.state);
        if st.severed || st.closed {
            return false;
        }
        if st.queue.len() >= self.cap {
            st.severed = true;
            drop(st);
            self.cv.notify_all();
            return false;
        }
        let seq = st.pushed;
        st.pushed += 1;
        if st.first_token.is_none() {
            st.first_token = Some(self.born.elapsed());
            st.first_token_ts_us = Some(crate::obs::clock::now_us());
        }
        st.queue.push_back(StreamEvent { seq, token, sibling });
        drop(st);
        self.cv.notify_all();
        true
    }

    /// Mark the terminal outcome as published. Pending tokens stay
    /// receivable; after they drain, `recv_timeout` reports `Closed`.
    pub fn close(&self) {
        lock_ok(&self.state).closed = true;
        self.cv.notify_all();
    }

    /// Whether the producer overran the buffer (slow consumer).
    pub fn is_severed(&self) -> bool {
        lock_ok(&self.state).severed
    }

    /// Tokens accepted into the stream so far.
    pub fn tokens_pushed(&self) -> u64 {
        lock_ok(&self.state).pushed
    }

    /// Time from sink creation (submission) to the first token entering
    /// the channel — TTFT as deliverable on the wire (includes router
    /// queueing and prefill; the engine-side `ttft` histogram starts
    /// later, at sequence admission). `None` until a token was pushed.
    pub fn wire_ttft(&self) -> Option<Duration> {
        lock_ok(&self.state).first_token
    }

    /// First-token instant on the shared monotonic engine clock
    /// ([`crate::obs::clock::now_us`]), for correlating wire delivery
    /// with flight-recorder spans. `None` until a token was pushed.
    pub fn first_token_ts_us(&self) -> Option<u64> {
        lock_ok(&self.state).first_token_ts_us
    }

    /// Receive the next event, blocking up to `timeout` (Condvar-
    /// signaled). Tokens drain in sequence order even after `close`.
    pub fn recv_timeout(&self, timeout: Duration) -> StreamRecv {
        let deadline = Instant::now() + timeout;
        let mut st = lock_ok(&self.state);
        loop {
            if let Some(ev) = st.queue.pop_front() {
                return StreamRecv::Event(ev);
            }
            if st.closed {
                return StreamRecv::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return StreamRecv::Empty;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_recv_in_order_then_closed() {
        let sink = StreamSink::new(8);
        assert!(sink.push_token(10, 0));
        assert!(sink.push_token(11, 0));
        sink.close();
        assert_eq!(
            sink.recv_timeout(Duration::from_millis(10)),
            StreamRecv::Event(StreamEvent { seq: 0, token: 10, sibling: 0 })
        );
        assert_eq!(
            sink.recv_timeout(Duration::from_millis(10)),
            StreamRecv::Event(StreamEvent { seq: 1, token: 11, sibling: 0 })
        );
        assert_eq!(sink.recv_timeout(Duration::from_millis(10)), StreamRecv::Closed);
        assert!(sink.wire_ttft().is_some());
        // Pushes after close are refused without severing semantics
        // mattering (the stream is already terminal).
        assert!(!sink.push_token(99, 0));
        assert_eq!(sink.tokens_pushed(), 2);
    }

    #[test]
    fn overflow_severs_and_never_drops_silently() {
        let sink = StreamSink::new(2);
        assert!(sink.push_token(1, 0));
        assert!(sink.push_token(2, 0));
        assert!(!sink.push_token(3, 0), "push into a full buffer must fail");
        assert!(sink.is_severed());
        assert!(!sink.push_token(4, 0), "a severed stream accepts nothing more");
        // Delivered tokens stay contiguous: 0, 1, then nothing past the
        // severing point until close.
        assert_eq!(
            sink.recv_timeout(Duration::from_millis(5)),
            StreamRecv::Event(StreamEvent { seq: 0, token: 1, sibling: 0 })
        );
        assert_eq!(
            sink.recv_timeout(Duration::from_millis(5)),
            StreamRecv::Event(StreamEvent { seq: 1, token: 2, sibling: 0 })
        );
        assert_eq!(sink.recv_timeout(Duration::from_millis(5)), StreamRecv::Empty);
        sink.close();
        assert_eq!(sink.recv_timeout(Duration::from_millis(5)), StreamRecv::Closed);
        assert_eq!(sink.tokens_pushed(), 2);
    }

    #[test]
    fn empty_timeout_does_not_close() {
        let sink = StreamSink::new(4);
        assert_eq!(sink.recv_timeout(Duration::from_millis(1)), StreamRecv::Empty);
        assert!(sink.wire_ttft().is_none());
    }
}
