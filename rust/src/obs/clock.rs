//! The single monotonic engine clock.
//!
//! Every observability timestamp in the process — flight-recorder trace
//! events, `reqlog` stderr lines, metrics snapshots — is microseconds
//! since one process-wide anchor, so per-worker ring dumps and request
//! logs merge-sort into one coherent timeline. The anchor is lazily
//! initialized on first use and never moves; the clock is monotonic
//! because [`std::time::Instant`] is.

use std::sync::OnceLock;
use std::time::Instant;

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Microseconds since the process-wide monotonic epoch (first call
/// anchors the epoch at 0).
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_shared() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
        // Two observers on different threads read the same epoch.
        let t = std::thread::spawn(now_us).join().unwrap();
        assert!(t >= a);
    }
}
