"""Synthetic training corpus with long-range structure.

Stand-in for the paper's PaulGrahamEssays / NeedleInAHaystack evaluation
data (Section 7): no dataset or network access exists in this environment,
so we synthesize byte-level text that (a) has enough local structure for a
tiny char-LM to learn something non-trivial, and (b) contains *long-range
dependencies* — "needle" facts stated once and referenced much later — so
that attention over distant context genuinely matters, which is the
property the top-r experiments need (see DESIGN.md §3).

Everything is deterministic from the seed.
"""

from __future__ import annotations

import numpy as np

VOCAB_SIZE = 256  # byte-level

_SUBJECTS = [
    "the merchant", "a courier", "the archivist", "our captain",
    "the gardener", "a scholar", "the engineer", "that piper",
    "the warden", "an envoy", "the mason", "a herald",
]
_VERBS = [
    "carries", "guards", "studies", "repairs", "paints", "sells",
    "hides", "records", "collects", "delivers", "forges", "maps",
]
_OBJECTS = [
    "copper coins", "sealed letters", "glass lenses", "star charts",
    "dried herbs", "iron keys", "silk banners", "clay tablets",
    "silver rings", "oak barrels", "wax seals", "old ledgers",
]
_PLACES = [
    "by the river", "near the gate", "under the bridge", "in the tower",
    "at the market", "beside the mill", "within the vault", "on the hill",
]

_NAMES = [
    "alder", "brook", "cedar", "dahlia", "ember", "fennel", "garnet",
    "hazel", "iris", "juniper", "koa", "laurel", "maple", "nettle",
]
_SECRETS = [
    "amber", "basalt", "cobalt", "dusk", "echo", "flint", "glow",
    "harbor", "ink", "jade", "kelp", "lumen", "moss", "nectar",
]


def _sentence(rng: np.random.Generator) -> str:
    return "{} {} {} {}. ".format(
        _SUBJECTS[rng.integers(len(_SUBJECTS))],
        _VERBS[rng.integers(len(_VERBS))],
        _OBJECTS[rng.integers(len(_OBJECTS))],
        _PLACES[rng.integers(len(_PLACES))],
    )


def _needle_fact(rng: np.random.Generator) -> tuple[str, str, str]:
    """A (statement, question, answer) needle triple."""
    name = _NAMES[rng.integers(len(_NAMES))]
    secret = _SECRETS[rng.integers(len(_SECRETS))]
    statement = f"remember: {name} keeps the {secret} token. "
    question = f"the {name} token is "
    answer = secret
    return statement, question, answer


def generate_document(rng: np.random.Generator, length: int, needle_period: int = 6) -> str:
    """One document: filler sentences with periodic needle statements whose
    answers are queried later in the same document."""
    parts: list[str] = []
    pending: list[tuple[str, str]] = []  # (question, answer) to emit later
    total = 0
    i = 0
    while total < length:
        if i % needle_period == needle_period - 1:
            statement, question, answer = _needle_fact(rng)
            parts.append(statement)
            total += len(statement)
            pending.append((question, answer))
        elif pending and rng.random() < 0.35:
            question, answer = pending.pop(rng.integers(len(pending)))
            ref = question + answer + ". "
            parts.append(ref)
            total += len(ref)
        else:
            s = _sentence(rng)
            parts.append(s)
            total += len(s)
        i += 1
    return "".join(parts)[:length]


def corpus_bytes(seed: int, total_bytes: int) -> np.ndarray:
    """Concatenated documents as a uint8 array of exactly `total_bytes`."""
    rng = np.random.default_rng(seed)
    chunks: list[np.ndarray] = []
    remaining = total_bytes
    while remaining > 0:
        doc_len = int(min(remaining, rng.integers(2_000, 6_000)))
        doc = generate_document(rng, doc_len)
        arr = np.frombuffer(doc.encode("ascii", errors="replace"), dtype=np.uint8)
        chunks.append(arr[:doc_len])
        remaining -= doc_len
    out = np.concatenate(chunks)[:total_bytes]
    assert out.dtype == np.uint8 and len(out) == total_bytes
    return out


def batches(data: np.ndarray, seq_len: int, batch_size: int, steps: int, seed: int):
    """Yield (inputs, targets) int32 batches for next-byte prediction."""
    rng = np.random.default_rng(seed)
    n = len(data) - seq_len - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch_size)
        x = np.stack([data[s : s + seq_len] for s in starts]).astype(np.int32)
        y = np.stack([data[s + 1 : s + seq_len + 1] for s in starts]).astype(np.int32)
        yield x, y


def eval_document(seed: int, length: int) -> np.ndarray:
    """A held-out document (distinct seed space) for perplexity evals."""
    rng = np.random.default_rng(seed + 10_000_019)
    doc = generate_document(rng, length)
    return np.frombuffer(doc.encode("ascii", errors="replace"), dtype=np.uint8)[:length]
