//! Bench/reproduction: **Theorem 4.3 / Lemma G.1** — approximation error
//! of Softmax attention with top-r indices.
//!
//! Sweeps r on (a) isotropic Gaussian scores (worst case — no massive
//! activation) and (b) planted massive-activation instances across γ,
//! comparing measured ℓ∞ error against both bounds. The Figure-3-shaped
//! conclusion: error is negligible except at very small r.

use hsr_attn::attention::error::{
    general_error_bound, v_inf_norm, MassiveActivation,
};
use hsr_attn::attention::softmax::{softmax_attention_row, softmax_attention_row_subset};
use hsr_attn::attention::topk::top_r_indices;
use hsr_attn::attention::{linf, scores_into};
use hsr_attn::bench::banner;
use hsr_attn::util::rng::Rng;
use hsr_attn::workloads::massive::planted;

fn main() {
    banner("error_topr", "paper Theorem 4.3 / Lemma G.1 (top-r softmax error)");
    let d = 16usize;
    let n = 4_096usize;
    let mut rng = Rng::new(17);

    // ---- (a) isotropic Gaussian: Lemma G.1 only ----
    println!("\n(a) isotropic Gaussian scores (no massive activation), n = {n}:");
    println!("{:>7} | {:>12} {:>14}", "r", "linf error", "Lemma G.1 bound");
    let q = rng.gaussian_vec_f32(d, 1.0);
    let k = rng.gaussian_vec_f32(n * d, 1.0);
    let v = rng.gaussian_vec_f32(n * d, 1.0);
    let mut scores = vec![0f32; n];
    scores_into(&q, &k, d, &mut scores);
    let mut buf = Vec::new();
    let mut dense = vec![0f32; d];
    softmax_attention_row(&q, &k, &v, d, &mut buf, &mut dense);
    for p in [2u32, 4, 6, 8, 10, 12] {
        let r = (1usize << p).min(n);
        let idx = top_r_indices(&scores, r);
        let mut approx = vec![0f32; d];
        softmax_attention_row_subset(&q, &k, &v, d, &idx, &mut buf, &mut approx);
        let err = linf(&dense, &approx);
        let bound = general_error_bound(&scores, &idx, v_inf_norm(&v));
        println!("{:>7} | {:>12.3e} {:>14.3e}", r, err, bound);
        assert!((err as f64) <= bound + 1e-5, "bound violated");
    }

    // ---- (b) planted massive activation: Theorem 4.3 ----
    println!("\n(b) planted (γ, β1, β2) massive activation, n = {n}:");
    println!(
        "{:>5} {:>6} {:>6} | {:>12} {:>13} {:>13}",
        "γ", "β1", "β2", "linf error", "G.1 bound", "Thm4.3 bound"
    );
    for &(gamma, beta1, beta2) in
        &[(0.3, 0.6, 0.2), (0.4, 0.8, 0.2), (0.5, 0.5, 0.3), (0.6, 0.9, 0.1)]
    {
        let inst = planted(&mut rng, n, d, gamma, beta1, beta2);
        // Definition B.3 / Theorem 4.3 use *unscaled* inner products.
        let raw: Vec<f32> = (0..n)
            .map(|i| hsr_attn::hsr::dot(&inst.q, &inst.k[i * d..(i + 1) * d]))
            .collect();
        let idx = top_r_indices(&raw, inst.top);
        let mut dense = vec![0f32; d];
        // Unscaled softmax == softmax over raw scores: emulate by passing
        // pre-scaled q' = q * sqrt(d).
        let qs: Vec<f32> = inst.q.iter().map(|&x| x * (d as f32).sqrt()).collect();
        softmax_attention_row(&qs, &inst.k, &inst.v, d, &mut buf, &mut dense);
        let mut approx = vec![0f32; d];
        softmax_attention_row_subset(&qs, &inst.k, &inst.v, d, &idx, &mut buf, &mut approx);
        let err = linf(&dense, &approx);
        let g1 = general_error_bound(&raw, &idx, v_inf_norm(&inst.v));
        let ma = MassiveActivation::measure(&inst.q, &inst.k, d, gamma);
        let t43 = ma.bound(n, v_inf_norm(&inst.v) as f64);
        println!(
            "{:>5.1} {:>6.2} {:>6.2} | {:>12.3e} {:>13.3e} {:>13.3e}",
            gamma, ma.beta1, ma.beta2, err, g1, t43
        );
        assert!((err as f64) <= g1 + 1e-5, "G.1 violated");
        assert!(g1 <= t43 * (1.0 + 1e-6), "Thm 4.3 should relax G.1");
    }
    println!("\nconclusion (matches paper §7): measured error ≤ G.1 ≤ Thm 4.3;");
    println!("errors are negligible except at very small r.");
}
