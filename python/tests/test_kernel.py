"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes/dtypes per deliverable (c): the kernel is the
paper's compute hot-spot, so this is the core correctness signal for L1.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hsr_attn as K
from compile.kernels import ref

ATOL = 2e-5
RTOL = 2e-4


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 5),
    r_max=st.integers(1, 300),
    d=st.sampled_from([4, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_softmax_matches_ref(m, r_max, d, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, m, d)
    kg = _rand(rng, m, r_max, d)
    vg = _rand(rng, m, r_max, d)
    count = jnp.asarray(rng.integers(0, r_max + 1, size=m), jnp.int32)
    got = K.masked_softmax_attention(q, kg, vg, count)
    want = ref.masked_softmax_attention(q, kg, vg, count)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 5),
    r_max=st.integers(1, 300),
    d=st.sampled_from([4, 16, 32]),
    alpha=st.sampled_from([1, 2, 3]),
    bias=st.floats(-1.0, 1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_relu_matches_ref(m, r_max, d, alpha, bias, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, m, d)
    kg = _rand(rng, m, r_max, d)
    vg = _rand(rng, m, r_max, d)
    count = jnp.asarray(rng.integers(0, r_max + 1, size=m), jnp.int32)
    got = K.masked_relu_attention(q, kg, vg, count, bias=bias, alpha=alpha)
    want = ref.masked_relu_attention(q, kg, vg, count, bias=bias, alpha=alpha)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 4),
    n_tiles=st.integers(1, 4),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_softmax_matches_ref(m, n_tiles, d, seed):
    rng = np.random.default_rng(seed)
    n = n_tiles * K.BLOCK_K
    q = _rand(rng, m, d)
    k = _rand(rng, n, d)
    v = _rand(rng, n, d)
    got = K.dense_softmax_attention(q, k, v)
    want = ref.softmax_attention(q, k, v)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)


def test_zero_count_rows_are_zero():
    rng = np.random.default_rng(0)
    q = _rand(rng, 2, 8)
    kg = _rand(rng, 2, 64, 8)
    vg = _rand(rng, 2, 64, 8)
    count = jnp.asarray([0, 0], jnp.int32)
    out_s = K.masked_softmax_attention(q, kg, vg, count)
    out_r = K.masked_relu_attention(q, kg, vg, count, bias=0.0, alpha=1)
    assert np.all(np.asarray(out_s) == 0.0)
    assert np.all(np.asarray(out_r) == 0.0)


def test_padding_rows_do_not_leak():
    """Huge values in padded rows must not affect the output."""
    rng = np.random.default_rng(1)
    q = _rand(rng, 1, 16)
    kg = np.asarray(_rand(rng, 1, 128, 16))
    vg = np.asarray(_rand(rng, 1, 128, 16))
    count = jnp.asarray([40], jnp.int32)
    base_s = K.masked_softmax_attention(jnp.asarray(q), jnp.asarray(kg), jnp.asarray(vg), count)
    kg2 = kg.copy()
    vg2 = vg.copy()
    kg2[:, 40:, :] = 1e4
    vg2[:, 40:, :] = -1e4
    poisoned = K.masked_softmax_attention(
        jnp.asarray(q), jnp.asarray(kg2), jnp.asarray(vg2), count
    )
    np.testing.assert_allclose(base_s, poisoned, atol=1e-6)


def test_relu_padding_rows_do_not_leak():
    rng = np.random.default_rng(2)
    q = _rand(rng, 1, 8)
    kg = np.asarray(_rand(rng, 1, 64, 8))
    vg = np.asarray(_rand(rng, 1, 64, 8))
    count = jnp.asarray([10], jnp.int32)
    base = K.masked_relu_attention(jnp.asarray(q), jnp.asarray(kg), jnp.asarray(vg), count, bias=0.1, alpha=2)
    kg2 = kg.copy()
    kg2[:, 10:, :] = 50.0
    poisoned = K.masked_relu_attention(
        jnp.asarray(q), jnp.asarray(kg2), jnp.asarray(vg), count, bias=0.1, alpha=2
    )
    np.testing.assert_allclose(base, poisoned, atol=1e-6)


def test_relu_sparse_equals_dense_on_activated_superset():
    """The paper's exactness claim: ReLU attention over any superset of
    the activated set equals the full computation (Section 2.2)."""
    rng = np.random.default_rng(3)
    n, d, bias, alpha = 200, 16, 0.3, 2
    q = _rand(rng, 1, d)
    k = _rand(rng, n, d)
    v = _rand(rng, n, d)
    dense = ref.relu_attention(q, k, v, bias=bias, alpha=alpha)
    scores = np.asarray(q @ k.T / np.sqrt(d))[0]
    act = np.where(scores - bias > 0)[0]
    # Superset: activated plus 7 random extras.
    extra = rng.choice(np.setdiff1d(np.arange(n), act), size=min(7, n - len(act)), replace=False)
    idx = np.concatenate([act, extra]).astype(np.int32)
    kg = jnp.asarray(np.asarray(k)[idx])[None]
    vg = jnp.asarray(np.asarray(v)[idx])[None]
    got = K.masked_relu_attention(q, kg, vg, jnp.asarray([len(idx)], jnp.int32), bias=bias, alpha=alpha)
    np.testing.assert_allclose(got, dense, atol=1e-5, rtol=1e-4)


def test_vmem_footprint_within_budget():
    """§Hardware-Adaptation: decode-step tile must fit VMEM (16 MB)."""
    r_max = 2 * int(65536 ** 0.8)  # Lemma 6.1 budget at n = 64k
    bytes_needed = K.vmem_footprint_bytes(r_max, 64)
    assert bytes_needed < 16 * 2**20
    assert 0.0 < K.mxu_utilization_estimate(r_max, 64) <= 1.0


@pytest.mark.parametrize("r_max", [1, 127, 128, 129, 256])
def test_nonmultiple_r_max_padding(r_max):
    rng = np.random.default_rng(4)
    q = _rand(rng, 2, 8)
    kg = _rand(rng, 2, r_max, 8)
    vg = _rand(rng, 2, r_max, 8)
    count = jnp.asarray([r_max, max(0, r_max - 1)], jnp.int32)
    got = K.masked_softmax_attention(q, kg, vg, count)
    want = ref.masked_softmax_attention(q, kg, vg, count)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=RTOL)
