//! Algorithm 2 — Prompt Prefilling.
//!
//! The paper's `PromptPrefilling` data structure: both Q and K vary per
//! call (m = Θ(n)), so the HSR structure is built *inside* INFERENCE with
//! the cheap Part-1 build and queried once per query row:
//!
//! ```text
//! INFERENCE({K_i}, {Q_r}, V, n, m, d):
//!   b ← σ_a √(0.4 log n)
//!   HSR.INIT({K_i}, n, d)                       (O(n log n))
//!   for i in 1..m:  S̃_i,fire ← HSR.QUERY(Q_i, b)
//!                   A_{i,j} ← ReLU^α(…)  or Softmax(…)
//!   return D^{-1} A V
//! ```
//!
//! Since the session API landed this type is a **thin caller** of
//! [`AttentionSession`]: INFERENCE builds a session over the keys (the
//! [`ThresholdPolicy::Lemma`] policy is exactly the b above) and calls
//! [`AttentionSession::run`] — which blocks the m query rows into
//! shared HSR traversals, shards them across scoped threads, and
//! evaluates through the bucketed gather, bit-identically for every
//! thread count. The struct is kept as a deprecated-style shim for one
//! release; new code should use [`AttentionConfig`] directly.

use crate::attention::session::{AttentionConfig, AttentionSession, ThresholdPolicy};
use crate::attention::AttentionKind;
use crate::hsr::{HsrBackend, QueryStats};

/// Output of one prefill run.
pub struct PrefillResult {
    /// Attention output, row-major [m, d].
    pub out: Vec<f32>,
    /// Activated entries per query row (the k̃_i of Lemma 6.1).
    pub fired: Vec<usize>,
    /// HSR work counters.
    pub stats: QueryStats,
}

/// Algorithm 2 configuration (deprecated shim over [`AttentionConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct PromptPrefilling {
    pub kind: AttentionKind,
    pub backend: HsrBackend,
    /// Softmax: keep only the top-r of each report (Theorem 5.2).
    pub top_r: Option<usize>,
    /// Override the Lemma 6.1 threshold (scaled-score units).
    pub bias_override: Option<f32>,
    /// Worker threads for the query-row loop: 0 → one per available
    /// core, 1 → serial. The result is bit-identical either way.
    pub threads: usize,
}

impl PromptPrefilling {
    pub fn new(kind: AttentionKind, backend: HsrBackend) -> PromptPrefilling {
        PromptPrefilling { kind, backend, top_r: None, bias_override: None, threads: 0 }
    }

    /// The equivalent unified config (prefill never uses the per-query
    /// adaptive threshold: its softmax top-r path keeps the fixed bias
    /// with the exactness fallback, as in Theorem 5.2).
    pub fn attention_config(&self) -> AttentionConfig {
        let mut cfg = AttentionConfig::new(self.kind, self.backend).with_threads(self.threads);
        cfg.threshold = match self.bias_override {
            Some(b) => ThresholdPolicy::Fixed(b),
            None => ThresholdPolicy::Lemma,
        };
        cfg.top_r = self.top_r;
        cfg
    }

    /// Build the per-call session: Part-1 HSR build over the keys.
    pub fn session(&self, keys: &[f32], d: usize) -> AttentionSession {
        self.attention_config().build(keys, d)
    }

    /// INFERENCE: full attention of Q, K, V (non-causal — the paper's
    /// prompt-prefilling / cross-attention setting).
    pub fn inference(
        &self,
        q: &[f32],
        keys: &[f32],
        values: &[f32],
        n: usize,
        m: usize,
        d: usize,
    ) -> PrefillResult {
        assert_eq!(q.len(), m * d);
        assert_eq!(keys.len(), n * d);
        assert_eq!(values.len(), n * d);
        let mut session = self.session(keys, d);
        let mut out = vec![0f32; m * d];
        let mut fired = vec![0usize; m];
        session.run(q, values, &mut out, &mut fired);
        PrefillResult { out, fired, stats: session.stats }
    }
}

// ---------------------------------------------------------------------------
// Serving-side chunked prefill × shared-prefix cache integration
// ---------------------------------------------------------------------------
//
// The serving engine's chunked prefill (serving.rs) brackets every chunk
// with these two hooks. Together they make a cohort of sequences sharing
// a prompt *cooperate*: each chunk of the common prefix is computed by
// whichever sequence gets there first and published; everyone else
// adopts it at their next chunk boundary and leapfrogs ahead — so in
// steady state each shared token is prefilled exactly once fleet-wide.

use super::metrics::Metrics;
use super::request::Sequence;
use crate::kvstore::PrefixStore;
use crate::model::kv::KvState;
use crate::model::ModelConfig;

/// Pre-chunk hook: re-match the prompt against the radix index and, if a
/// cached chain now covers **everything this sequence has prefilled so
/// far** and strictly more than its current chain, adopt it: drop the
/// private tail (every dropped row is covered by the chain — identical
/// tokens at identical positions, so nothing is lost), release its
/// blocks, take references on the new chain, seed the fresh tail's
/// calibration from the chain's snapshot, and jump `prefilled` forward.
/// Returns true if an adoption happened.
pub(crate) fn adopt_cached_prefix(
    store: &mut PrefixStore,
    seq: &mut Sequence,
    metrics: &mut Metrics,
    model_cfg: &ModelConfig,
    hsr_backend: Option<crate::hsr::HsrBackend>,
    refault_token_budget: usize,
) -> bool {
    if !store.enabled() || seq.prefilled >= seq.prompt.len() {
        return false;
    }
    // The lookup transparently refaults cold (spilled) chain nodes
    // within the budget; any evictions it performed to make room are
    // accounted here regardless of whether the chain is adopted.
    let (chain, matched) = store.lookup_budgeted(&seq.prompt, refault_token_budget);
    metrics.prefix_segments_evicted += store.take_refault_evictions() as u64;
    // Adopt only when the chain covers the whole computed tail (partial
    // tail drops would need row splicing) and strictly extends coverage.
    // Re-matches that merely confirm existing coverage are NOT counted
    // as lookups — `prefix_lookups` tallies admission probes plus
    // successful adoptions, so a perfectly-covering cache reads as a
    // high hit rate instead of one hit drowned in per-chunk "misses".
    if matched < seq.prefilled || matched <= seq.prefix_len || chain == seq.prefix {
        return false;
    }
    metrics.prefix_lookups += 1;
    store.radix.ref_chain(&chain);
    store.radix.deref_chain(&seq.prefix);
    store.pool.release(&mut seq.blocks);
    seq.kv = KvState::new(
        model_cfg.n_layers,
        model_cfg.n_heads,
        model_cfg.d_head,
        hsr_backend,
    );
    metrics.prefix_hits += 1;
    metrics.prefill_tokens_skipped += (matched - seq.prefilled) as u64;
    seq.prefix = chain;
    seq.prefix_len = matched;
    seq.prefilled = matched;
    store.seed_calib(&seq.prefix, &mut seq.kv);
    true
}

/// Post-chunk hook: publish the freshly prefilled prompt range into the
/// radix cache so sibling sequences (and future requests) can adopt it.
/// Publishes `prompt[covered..upto)` where `covered` is whatever the
/// radix already holds along this prompt and `upto` stops one short of
/// the prompt end (the last token is always recomputed). Best-effort:
/// skipped when the pool cannot spare the pages plus the scheduler's
/// headroom, or when another sequence's chain diverged from ours.
pub(crate) fn publish_prefix(
    store: &mut PrefixStore,
    seq: &Sequence,
    metrics: &mut Metrics,
    headroom_blocks: usize,
) -> bool {
    if !store.enabled() || seq.prompt.len() < 2 {
        return false;
    }
    let upto = seq.prefilled.min(seq.prompt.len() - 1);
    if upto <= seq.prefix_len {
        return false; // nothing computed beyond the adopted chain
    }
    let (chain, covered) =
        store.radix.match_chain(&store.pool, &seq.prompt, upto);
    if covered >= upto {
        return false; // already cached this far
    }
    // Our tail rows start at prefix_len; we can only publish ranges we
    // actually computed, under a chain that extends our own.
    if covered < seq.prefix_len || chain.len() < seq.prefix.len() {
        return false;
    }
    if chain[..seq.prefix.len()] != seq.prefix[..] {
        return false; // divergent sibling chain — do not cross-publish
    }
    // Keep the parent chain alive while eviction makes room.
    store.radix.ref_chain(&chain);
    let need = store.pool.blocks_for(upto - covered) + headroom_blocks;
    if store.pool.free_blocks() < need {
        let evicted = store.radix.evict_lru(&mut store.pool, need);
        metrics.prefix_segments_evicted += evicted as u64;
    }
    let node = store.publish_segment(
        chain.last().copied(),
        &seq.prompt[covered..upto],
        covered,
        &seq.kv,
        covered - seq.prefix_len,
        headroom_blocks,
    );
    store.radix.deref_chain(&chain);
    match node {
        Some(_) => {
            metrics.prefix_tokens_inserted += (upto - covered) as u64;
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::relu::relu_attention;
    use crate::attention::{linf, AttentionKind};
    use crate::util::rng::Rng;
    use crate::workloads::gaussian::AttentionInstance;

    #[test]
    fn relu_prefill_matches_dense() {
        let mut rng = Rng::new(111);
        let inst = AttentionInstance::gaussian(&mut rng, 150, 150, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        for backend in [HsrBackend::Brute, HsrBackend::BallTree] {
            let pp = PromptPrefilling {
                kind: AttentionKind::Relu { alpha: 2, bias },
                backend,
                top_r: None,
                bias_override: Some(bias),
                threads: 0,
            };
            let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
            let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 2, bias);
            assert!(linf(&res.out, &want) < 1e-4, "backend={backend:?}");
            assert_eq!(res.fired.len(), inst.m);
        }
    }

    #[test]
    fn layers2d_backend_for_d2() {
        let mut rng = Rng::new(112);
        let inst = AttentionInstance::gaussian(&mut rng, 60, 200, 2);
        let bias = 0.1f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::Layers2d,
            top_r: None,
            bias_override: Some(bias),
            threads: 0,
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let want = relu_attention(&inst.q, &inst.k, &inst.v, inst.d, 1, bias);
        assert!(linf(&res.out, &want) < 1e-4);
    }

    #[test]
    fn softmax_topr_stays_close_to_dense() {
        let mut rng = Rng::new(113);
        let inst = AttentionInstance::gaussian(&mut rng, 100, 400, 8);
        let mut pp = PromptPrefilling::new(AttentionKind::Softmax, HsrBackend::BallTree);
        pp.bias_override = Some(f32::NEG_INFINITY);
        pp.top_r = Some(128);
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let dense = crate::attention::softmax::softmax_attention(&inst.q, &inst.k, &inst.v, inst.d);
        // 128 of 400 top entries carries most of the exp mass; isotropic
        // Gaussian scores are the *worst* case for top-r truncation (no
        // massive activation), so the tolerance here is loose. The
        // massive-activation sweep in benches/error_topr.rs is the sharp
        // version of this check.
        assert!(linf(&res.out, &dense) < 0.3, "err={}", linf(&res.out, &dense));
        assert!(res.fired.iter().all(|&f| f <= 128));
    }

    #[test]
    fn fired_counts_respect_lemma_bound() {
        let mut rng = Rng::new(114);
        let inst = AttentionInstance::gaussian(&mut rng, 64, 2048, 16);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let pp = PromptPrefilling {
            kind: AttentionKind::Relu { alpha: 1, bias },
            backend: HsrBackend::BallTree,
            top_r: None,
            bias_override: Some(bias),
            threads: 0,
        };
        let res = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
        let bound = inst.params.row_bound(inst.n) as usize;
        assert!(res.fired.iter().all(|&f| f <= bound));
        assert!(res.fired.iter().sum::<usize>() > 0);
    }

    /// Parallel prefill must be **bit-identical** to serial: same `out`
    /// floats, same per-row fired counts, same merged work counters —
    /// for both attention kinds, with and without top-r. (Shards align
    /// to the session's query blocks, so even the shared-traversal
    /// `nodes_visited` is thread-count independent.)
    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut rng = Rng::new(115);
        let inst = AttentionInstance::gaussian(&mut rng, 64, 512, 8);
        let bias = inst.params.practical_bias(inst.n) as f32;
        let cases: Vec<PromptPrefilling> = vec![
            PromptPrefilling {
                kind: AttentionKind::Relu { alpha: 2, bias },
                backend: HsrBackend::BallTree,
                top_r: None,
                bias_override: Some(bias),
                threads: 1,
            },
            PromptPrefilling {
                kind: AttentionKind::Softmax,
                backend: HsrBackend::BallTree,
                top_r: Some(64),
                bias_override: Some(f32::NEG_INFINITY),
                threads: 1,
            },
            PromptPrefilling {
                kind: AttentionKind::Softmax,
                backend: HsrBackend::Brute,
                top_r: Some(32),
                bias_override: Some(bias),
                threads: 1,
            },
        ];
        for mut pp in cases {
            pp.threads = 1;
            let serial = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
            for threads in [2usize, 3, 7] {
                pp.threads = threads;
                let par = pp.inference(&inst.q, &inst.k, &inst.v, inst.n, inst.m, inst.d);
                assert_eq!(serial.out, par.out, "threads={threads} kind={:?}", pp.kind);
                assert_eq!(serial.fired, par.fired, "threads={threads}");
                assert_eq!(serial.stats, par.stats, "threads={threads}");
            }
        }
    }

    /// The session path reuses its plan arenas: planning the same rows
    /// twice through one session must not lose buffer capacity (the
    /// pre-kernel code once `mem::take`-d a buffer and re-allocated
    /// every row; this is the session-era version of that regression
    /// test).
    #[test]
    fn plan_buffers_survive_reuse() {
        let mut rng = Rng::new(116);
        let inst = AttentionInstance::gaussian(&mut rng, 16, 256, 8);
        let pp = PromptPrefilling {
            kind: AttentionKind::Softmax,
            backend: HsrBackend::BallTree,
            top_r: Some(16),
            bias_override: Some(f32::NEG_INFINITY),
            threads: 1,
        };
        let session = pp.session(&inst.k, inst.d);
        let mut plan = crate::attention::AttentionPlan::new();
        session.plan_into(&inst.q, &mut plan);
        let first: Vec<usize> = plan.fired.clone();
        // Full report: every row fires all n entries before top-r.
        let cap_after_first = plan.fired.capacity();
        session.plan_into(&inst.q, &mut plan);
        assert_eq!(plan.fired, first, "replanning must be deterministic");
        assert_eq!(
            plan.fired.capacity(),
            cap_after_first,
            "plan arenas must retain capacity across reuse"
        );
    }
}
