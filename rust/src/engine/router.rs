//! Request router: shards requests across supervised engine worker
//! threads (vllm-project/router-shaped, scaled to this testbed). Each
//! worker owns one [`Engine`] replica behind a `Mutex`+`Condvar` inbox;
//! the router picks the least-loaded live worker, enforces admission
//! control (bounded per-worker queue depth + a pool-wide in-flight cap),
//! and merges metrics/outcomes.
//!
//! # Failure model
//!
//! * [`Router::submit`] returns `Result` — a saturated or stopping pool
//!   sheds load with [`SubmitError`] instead of queueing unboundedly
//!   (and never panics the accept path: no `expect` on worker state).
//! * Each worker wraps its engine turn in `catch_unwind`. On a panic
//!   (injected via [`FaultPlan`](super::serving::FaultPlan) or real)
//!   the worker marks itself dead, salvages its in-flight requests,
//!   restarts in place with a fresh engine (the fault plan cleared so a
//!   deterministic fault fires once), re-dispatches never-decoded
//!   requests to live workers under a bounded retry budget, and answers
//!   the rest with a structured [`Outcome::Failed`].
//! * Completion is event-driven: outcomes land in a Condvar-signaled
//!   table ([`Router::wait_for_outcome`] / [`Router::wait_idle`] block
//!   on the Condvar — no sleep-polling on the request path).
//! * [`Router::cancel`] removes a queued request from its inbox
//!   outright, or broadcasts to the engines so the owner aborts it
//!   mid-decode (releasing its KV blocks and chain refs).
//! * [`Router::submit_streaming`] hands back a bounded [`StreamSink`]
//!   the engine pushes tokens through; the sink closes exactly when the
//!   request's terminal outcome lands, extending the exactly-one
//!   terminal outcome invariant to mid-stream failures (panic, deadline,
//!   disconnect, slow consumer).
//!
//! # Routing policy
//!
//! Dispatch follows a prefix-affinity ladder (see
//! [`Shared::route_worker`]): a router-side [`PrefixSketch`] maps
//! recent prompt prefixes to the worker whose private radix cache
//! holds them; prompts follow the sketch when the preferred worker is
//! alive and under its queue bound, and degrade to least-loaded (with
//! a deterministic lowest-index tie-break) otherwise — a cache hint
//! never becomes an availability loss.

use super::metrics::Metrics;
use super::request::{FinishReason, GenerationParams, Request, RequestId, Response};
use super::serving::{Engine, EngineConfig, FaultPlan};
use super::stream::StreamSink;
use crate::model::Model;
use crate::util::stats::Histogram;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Admission-control and supervision knobs.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Per-worker bound on queued + running requests; submission skips
    /// workers at the bound.
    pub max_queue_per_worker: usize,
    /// Pool-wide in-flight cap; beyond it `submit` sheds load.
    pub max_in_flight: usize,
    /// Re-dispatch budget for requests salvaged from a panicked worker.
    pub max_retries: u32,
    /// Retry hint attached to `Overloaded` rejections.
    pub retry_after_ms: u64,
    /// Prefix-affinity routing: prompts whose prefix was recently
    /// dispatched to a worker are routed back to that worker (its
    /// private radix cache already holds the prefix). Degrades to
    /// least-loaded whenever the preferred worker is dead, at its queue
    /// bound, or the sketch probe is contended — a cache hint never
    /// becomes an availability loss.
    pub affinity: bool,
    /// Per-stream send-buffer capacity in tokens. A consumer that falls
    /// this far behind severs its stream (terminal `slow_consumer`
    /// error) instead of blocking decode or growing memory.
    pub stream_buffer: usize,
    /// Emit one structured `reqlog` line (stderr) per terminal outcome:
    /// id, prompt length, tokens, finish reason / error code, latency,
    /// ttft, owning worker, affinity decision, retry count. Off by
    /// default; the serve CLI turns it on.
    pub request_log: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            max_queue_per_worker: 64,
            max_in_flight: 512,
            max_retries: 2,
            retry_after_ms: 50,
            affinity: true,
            stream_buffer: 256,
            request_log: false,
        }
    }
}

/// Why a submission was refused at the door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// Admission control shed the request; retry after the hint.
    Overloaded { retry_after_ms: u64 },
    /// The router is draining; no new work is accepted.
    ShuttingDown,
    /// Every worker is dead (mid-restart window).
    NoWorkers,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded (retry after {retry_after_ms} ms)")
            }
            SubmitError::ShuttingDown => write!(f, "shutting down"),
            SubmitError::NoWorkers => write!(f, "no live workers"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Terminal failure of an accepted request (structured error line on
/// the wire: `code` + `message` + optional retry hint).
#[derive(Debug, Clone)]
pub struct RequestError {
    pub id: RequestId,
    pub code: &'static str,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

/// Exactly-one terminal outcome per accepted request.
#[derive(Debug, Clone)]
pub enum Outcome {
    Done(Response),
    Failed(RequestError),
}

impl Outcome {
    pub fn id(&self) -> RequestId {
        match self {
            Outcome::Done(r) => r.id,
            Outcome::Failed(e) => e.id,
        }
    }
}

enum WorkerMsg {
    Submit(Request),
    Cancel(RequestId),
    Shutdown { abort: bool },
}

/// Per-worker mailbox + liveness, shared so a dying worker can reach
/// survivors' inboxes when re-dispatching salvaged requests.
struct WorkerState {
    inbox: Mutex<VecDeque<WorkerMsg>>,
    cv: Condvar,
    /// Queued + running requests owned by this worker.
    in_flight: AtomicUsize,
    alive: AtomicBool,
    /// Latest metrics snapshot published by the worker after each engine
    /// turn, so live `stats_snapshot()` scrapes see in-flight progress.
    /// Cleared (under `Shared::metrics` → `published` lock order) when
    /// the engine's counters merge into `Shared::metrics` at worker exit
    /// or panic — a worker's counters are never counted twice.
    published: Mutex<Metrics>,
}

#[derive(Default)]
struct CompletionState {
    ready: HashMap<RequestId, Outcome>,
    completed: usize,
}

#[derive(Default)]
struct Completions {
    state: Mutex<CompletionState>,
    cv: Condvar,
}

/// Per-request routing facts the terminal `reqlog` line reports —
/// recorded at dispatch (and updated on salvage re-dispatch), popped
/// exactly once when the outcome is published. Only maintained when
/// `RouterConfig::request_log` is on.
struct ReqMeta {
    prompt_len: usize,
    /// How routing picked the worker: `hit` (affinity sketch honored),
    /// `fallback` (sketch named a dead/saturated worker), `none` (no
    /// sketch entry / affinity off / salvage re-dispatch).
    affinity: &'static str,
    worker: usize,
    attempts: u32,
}

/// Prefix grains (token counts) the affinity sketch records, probed
/// longest-first so the most specific recent routing wins.
const SKETCH_GRAINS: [usize; 3] = [256, 64, 16];
/// Sketch size bound; ~25% oldest entries are dropped on overflow.
const SKETCH_CAP: usize = 4096;

/// FNV-1a over the first `grain` prompt tokens, with the grain mixed in
/// so different granularities occupy disjoint key spaces.
fn prefix_hash(prompt: &[u32], grain: usize) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64
        ^ (grain as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    for &t in &prompt[..grain] {
        h ^= t as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Router-side prefix-affinity sketch: a bounded map from prompt-prefix
/// hashes to the worker that last received a prompt with that prefix.
/// It is a *hint* mirroring where each worker's private `RadixIndex`
/// likely holds cached segments — cheap to probe on the submit path
/// (no worker lock crosses it), and safe to be stale: a wrong hint
/// costs one cache miss, never correctness, and the degradation ladder
/// in [`Shared::route_worker`] keeps it from costing availability.
#[derive(Default)]
struct PrefixSketch {
    /// prefix hash → (worker index, last-touch stamp).
    map: HashMap<u64, (usize, u64)>,
    clock: u64,
}

impl PrefixSketch {
    /// Grain clamped the same way `PrefixStore::lookup` caps matches:
    /// at most `prompt.len() - 1` tokens (the last token is never
    /// cached — its logits seed the first generated token).
    fn grain_for(prompt: &[u32], grain: usize) -> usize {
        grain.min(prompt.len().saturating_sub(1))
    }

    /// Record that `prompt` was dispatched to `widx`.
    fn note(&mut self, prompt: &[u32], widx: usize) {
        self.clock += 1;
        let stamp = self.clock;
        for grain in SKETCH_GRAINS {
            let g = Self::grain_for(prompt, grain);
            if g == 0 {
                continue;
            }
            self.map.insert(prefix_hash(prompt, g), (widx, stamp));
        }
        if self.map.len() > SKETCH_CAP {
            let cutoff = self.clock.saturating_sub(SKETCH_CAP as u64 / 4);
            self.map.retain(|_, &mut (_, s)| s > cutoff);
        }
    }

    /// The worker that last saw a prompt sharing a prefix with this
    /// one, longest grain first.
    fn candidate(&self, prompt: &[u32]) -> Option<usize> {
        for grain in SKETCH_GRAINS {
            let g = Self::grain_for(prompt, grain);
            if g == 0 {
                continue;
            }
            if let Some(&(w, _)) = self.map.get(&prefix_hash(prompt, g)) {
                return Some(w);
            }
        }
        None
    }
}

struct Shared {
    model: Arc<Model>,
    cfg: EngineConfig,
    rcfg: RouterConfig,
    workers: Vec<WorkerState>,
    completions: Completions,
    submitted: AtomicUsize,
    next_id: AtomicU64,
    stopping: AtomicBool,
    // Router-level robustness counters, merged into Metrics at shutdown.
    rejected: AtomicU64,
    failed: AtomicU64,
    cancelled_in_queue: AtomicU64,
    worker_panics: AtomicU64,
    worker_restarts: AtomicU64,
    queue_depth_peak: AtomicU64,
    affinity_hits: AtomicU64,
    affinity_fallbacks: AtomicU64,
    streams_severed: AtomicU64,
    /// Prefix-affinity routing sketch (see [`PrefixSketch`]).
    sketch: Mutex<PrefixSketch>,
    /// Routing facts for the per-request log (empty unless
    /// `RouterConfig::request_log`).
    reqlog: Mutex<HashMap<RequestId, ReqMeta>>,
    /// Live stream sinks by request id; a sink leaves this registry —
    /// and is closed — exactly when its terminal outcome is recorded,
    /// which is what gives streaming consumers the exactly-one-terminal
    /// frame guarantee.
    streams: Mutex<HashMap<RequestId, Arc<StreamSink>>>,
    /// Wire-visible TTFT (consumer-side first-token receipt).
    ttft_wire: Mutex<Histogram>,
    /// Metrics from exited/panicked engines (each engine's counters are
    /// merged here exactly once).
    metrics: Mutex<Metrics>,
}

/// Mutex access that survives a poisoned lock (a panicking worker never
/// holds these locks across engine code, but supervision should not be
/// taken down by a poisoned mutex either way).
fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Wire-style tag for a finish reason (the `finish=` field of reqlog
/// lines; matches the server's frame vocabulary).
fn finish_tag(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::StopToken => "stop",
        FinishReason::Aborted => "aborted",
        FinishReason::DeadlineExceeded => "deadline",
        FinishReason::Cancelled => "cancelled",
    }
}

/// Least-loaded selection over `(worker index, load)` pairs with a
/// deterministic tie-break: among equal loads the **lowest worker
/// index** wins, so routing decisions are reproducible run-to-run (a
/// `FaultPlan` targeting worker W hits the same requests every time).
fn least_loaded(candidates: impl Iterator<Item = (usize, usize)>) -> Option<usize> {
    candidates.min_by_key(|&(i, load)| (load, i)).map(|(i, _)| i)
}

impl Shared {
    /// Least-loaded live worker (deterministic lowest-index tie-break);
    /// `respect_caps` also skips workers at the queue bound.
    fn pick_worker(&self, respect_caps: bool) -> Option<usize> {
        least_loaded(self.workers.iter().enumerate().filter_map(|(i, w)| {
            if !w.alive.load(Ordering::Acquire) {
                return None;
            }
            let load = w.in_flight.load(Ordering::Relaxed);
            if respect_caps && load >= self.rcfg.max_queue_per_worker {
                return None;
            }
            Some((i, load))
        }))
    }

    /// Pick the dispatch worker for `prompt` via the affinity ladder:
    ///
    /// 1. Sketch names a worker that is alive and under its queue bound
    ///    → route there (`affinity_hits`); its radix cache likely holds
    ///    the prefix.
    /// 2. Sketch names a worker but it is dead or saturated → fall back
    ///    to least-loaded (`affinity_fallbacks`); the hint must never
    ///    cost availability.
    /// 3. Sketch probe contended (another submitter holds it — the
    ///    "probe timed out" rung) or no candidate → least-loaded.
    ///
    /// Returns the worker plus the affinity tag the per-request log
    /// reports: `hit`, `fallback`, or `none`.
    fn route_worker(&self, prompt: &[u32]) -> Option<(usize, &'static str)> {
        if self.rcfg.affinity {
            let candidate = match self.sketch.try_lock() {
                Ok(sk) => sk.candidate(prompt),
                Err(_) => None, // contended probe: degrade, don't wait
            };
            if let Some(w) = candidate {
                let ws = &self.workers[w];
                if ws.alive.load(Ordering::Acquire)
                    && ws.in_flight.load(Ordering::Relaxed)
                        < self.rcfg.max_queue_per_worker
                {
                    self.affinity_hits.fetch_add(1, Ordering::Relaxed);
                    return Some((w, "hit"));
                }
                self.affinity_fallbacks.fetch_add(1, Ordering::Relaxed);
                return self.pick_worker(true).map(|w| (w, "fallback"));
            }
        }
        self.pick_worker(true).map(|w| (w, "none"))
    }

    /// Record where `prompt` landed so future prompts sharing its
    /// prefix follow it.
    fn note_affinity(&self, prompt: &[u32], widx: usize) {
        if self.rcfg.affinity {
            lock_ok(&self.sketch).note(prompt, widx);
        }
    }

    fn total_in_flight(&self) -> usize {
        self.workers
            .iter()
            .map(|w| w.in_flight.load(Ordering::Relaxed))
            .sum()
    }

    fn note_queue_depth(&self) {
        self.queue_depth_peak
            .fetch_max(self.total_in_flight() as u64, Ordering::Relaxed);
    }

    fn enqueue(&self, widx: usize, msg: WorkerMsg) {
        let w = &self.workers[widx];
        lock_ok(&w.inbox).push_back(msg);
        w.cv.notify_one();
    }

    /// Dispatch to the least-loaded live worker, ignoring queue caps
    /// (used for salvage re-dispatch); returns the request when no
    /// worker is live.
    fn dispatch(&self, req: Request) -> Result<usize, Request> {
        match self.pick_worker(false) {
            Some(widx) => {
                self.workers[widx].in_flight.fetch_add(1, Ordering::Relaxed);
                self.note_queue_depth();
                self.note_affinity(&req.prompt, widx);
                if self.rcfg.request_log {
                    // Re-dispatch after salvage: move the log entry to
                    // the new owner and record the retry.
                    let mut log = lock_ok(&self.reqlog);
                    let meta = log.entry(req.id).or_insert(ReqMeta {
                        prompt_len: req.prompt.len(),
                        affinity: "none",
                        worker: widx,
                        attempts: req.attempts,
                    });
                    meta.worker = widx;
                    meta.attempts = req.attempts;
                }
                self.enqueue(widx, WorkerMsg::Submit(req));
                Ok(widx)
            }
            None => Err(req),
        }
    }

    /// Record a terminal outcome and wake every waiter. For streaming
    /// requests this is also the single place the sink is closed: the
    /// outcome is inserted *first*, then the sink — so a consumer that
    /// observes `Closed` is guaranteed to find the outcome it needs to
    /// render its one terminal frame.
    fn finish_outcome(&self, outcome: Outcome) {
        let id = outcome.id();
        if self.rcfg.request_log {
            self.log_outcome(&outcome);
        }
        let clean = matches!(
            &outcome,
            Outcome::Done(r)
                if matches!(r.finish, FinishReason::Length | FinishReason::StopToken)
        );
        {
            let mut st = lock_ok(&self.completions.state);
            st.ready.insert(id, outcome);
            st.completed += 1;
        }
        self.completions.cv.notify_all();
        let sink = lock_ok(&self.streams).remove(&id);
        if let Some(sink) = sink {
            if sink.tokens_pushed() > 0 && !clean {
                // Tokens went out but the stream did not finish cleanly
                // — the wire-visible truncation the terminal frame
                // reports.
                self.streams_severed.fetch_add(1, Ordering::Relaxed);
            }
            sink.close();
            if let Some(d) = sink.wire_ttft() {
                lock_ok(&self.ttft_wire).record(d);
            }
        }
    }

    /// One structured log line per terminal outcome (stderr, so stdout
    /// stays clean for bench/CLI output). The routing facts come from
    /// the reqlog ledger, popped here — exactly once per request, since
    /// every accepted request reaches exactly one terminal outcome.
    fn log_outcome(&self, outcome: &Outcome) {
        let id = outcome.id();
        let meta = lock_ok(&self.reqlog).remove(&id);
        let (worker, affinity, retries, meta_prompt) = match &meta {
            Some(m) => (m.worker as i64, m.affinity, m.attempts, m.prompt_len),
            // Cancelled-in-queue before dispatch logging, or logging
            // toggled on a live router: report what we have.
            None => (-1, "none", 0, 0),
        };
        // Same monotonic clock as trace events and stats snapshots, so
        // reqlog lines merge-sort into one timeline with trace dumps.
        let ts = crate::obs::clock::now_us();
        match outcome {
            Outcome::Done(r) => eprintln!(
                "reqlog ts_us={} id={} outcome=done finish={} prompt={} tokens={} \
                 latency_ms={:.1} ttft_ms={:.1} worker={} affinity={} retries={}",
                ts,
                id,
                finish_tag(r.finish),
                r.prompt_len,
                r.tokens.len(),
                r.latency_ms,
                r.ttft_ms,
                worker,
                affinity,
                retries,
            ),
            Outcome::Failed(e) => eprintln!(
                "reqlog ts_us={} id={} outcome=failed code={} prompt={} tokens=0 \
                 latency_ms=0.0 ttft_ms=0.0 worker={} affinity={} retries={}",
                ts, id, e.code, meta_prompt, worker, affinity, retries,
            ),
        }
    }

    /// Outcome from worker `widx`: the request leaves its ledger.
    fn publish(&self, widx: usize, outcome: Outcome) {
        self.workers[widx].in_flight.fetch_sub(1, Ordering::Relaxed);
        self.finish_outcome(outcome);
    }

    /// Terminal structured error for a request no worker owns anymore.
    fn fail(&self, id: RequestId, code: &'static str, message: String) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.finish_outcome(Outcome::Failed(RequestError {
            id,
            code,
            message,
            retry_after_ms: None,
        }));
    }
}

/// Multi-worker router with supervision.
pub struct Router {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Router {
    /// Spawn `n_workers` engines over a shared model with default
    /// admission control.
    pub fn new(model: Arc<Model>, cfg: EngineConfig, n_workers: usize) -> Router {
        Router::with_config(model, cfg, n_workers, RouterConfig::default())
    }

    pub fn with_config(
        model: Arc<Model>,
        cfg: EngineConfig,
        n_workers: usize,
        rcfg: RouterConfig,
    ) -> Router {
        assert!(n_workers >= 1);
        let workers = (0..n_workers)
            .map(|_| WorkerState {
                inbox: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                in_flight: AtomicUsize::new(0),
                alive: AtomicBool::new(true),
                published: Mutex::new(Metrics::default()),
            })
            .collect();
        let shared = Arc::new(Shared {
            model,
            cfg,
            rcfg,
            workers,
            completions: Completions::default(),
            submitted: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            stopping: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled_in_queue: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            queue_depth_peak: AtomicU64::new(0),
            affinity_hits: AtomicU64::new(0),
            affinity_fallbacks: AtomicU64::new(0),
            streams_severed: AtomicU64::new(0),
            sketch: Mutex::new(PrefixSketch::default()),
            reqlog: Mutex::new(HashMap::new()),
            streams: Mutex::new(HashMap::new()),
            ttft_wire: Mutex::new(Histogram::default()),
            metrics: Mutex::new(Metrics::default()),
        });
        let handles = (0..n_workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("engine-{w}"))
                    .spawn(move || worker_loop(w, shared))
                    .expect("spawn engine worker")
            })
            .collect();
        Router { shared, handles: Mutex::new(handles) }
    }

    /// Submit a buffered (whole-response) request via the affinity
    /// ladder. Sheds load (never panics, never blocks on a worker) when
    /// the pool is saturated, draining, or dead; ids are
    /// router-assigned and globally unique.
    pub fn submit(
        &self,
        prompt: Vec<u32>,
        params: GenerationParams,
    ) -> Result<RequestId, SubmitError> {
        self.submit_inner(prompt, params, None)
    }

    /// Submit a streaming request: tokens are delivered through the
    /// returned [`StreamSink`] as they decode, and the sink closes
    /// exactly when the request's terminal [`Outcome`] is published —
    /// after draining the sink to `Closed`, `wait_for_outcome` is
    /// guaranteed to find the outcome immediately. The sink buffers at
    /// most `RouterConfig::stream_buffer` undelivered tokens; a
    /// consumer that falls further behind severs the stream and the
    /// engine sheds the request (terminal `slow_consumer` semantics)
    /// rather than blocking decode.
    pub fn submit_streaming(
        &self,
        prompt: Vec<u32>,
        params: GenerationParams,
    ) -> Result<(RequestId, Arc<StreamSink>), SubmitError> {
        let sink = Arc::new(StreamSink::new(self.shared.rcfg.stream_buffer));
        let id = self.submit_inner(prompt, params, Some(Arc::clone(&sink)))?;
        Ok((id, sink))
    }

    fn submit_inner(
        &self,
        prompt: Vec<u32>,
        params: GenerationParams,
        stream: Option<Arc<StreamSink>>,
    ) -> Result<RequestId, SubmitError> {
        let s = &self.shared;
        if s.stopping.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        if s.total_in_flight() >= s.rcfg.max_in_flight {
            s.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Overloaded { retry_after_ms: s.rcfg.retry_after_ms });
        }
        let Some((widx, affinity)) = s.route_worker(&prompt) else {
            let any_alive = s.workers.iter().any(|w| w.alive.load(Ordering::Acquire));
            s.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(if any_alive {
                SubmitError::Overloaded { retry_after_ms: s.rcfg.retry_after_ms }
            } else {
                SubmitError::NoWorkers
            });
        };
        let id = s.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        s.submitted.fetch_add(1, Ordering::SeqCst);
        s.workers[widx].in_flight.fetch_add(1, Ordering::Relaxed);
        s.note_queue_depth();
        s.note_affinity(&prompt, widx);
        if s.rcfg.request_log {
            lock_ok(&s.reqlog).insert(
                id,
                ReqMeta { prompt_len: prompt.len(), affinity, worker: widx, attempts: 0 },
            );
        }
        if let Some(sink) = &stream {
            lock_ok(&s.streams).insert(id, Arc::clone(sink));
        }
        s.enqueue(widx, WorkerMsg::Submit(Request { id, prompt, params, attempts: 0, stream }));
        Ok(id)
    }

    /// Cancel a request: if it is still queued in an inbox it is
    /// removed there (terminal `Cancelled` outcome, true returned);
    /// otherwise a cancel is broadcast so the owning engine aborts it
    /// mid-decode (false — delivery is asynchronous, and a request that
    /// already finished is a no-op).
    pub fn cancel(&self, id: RequestId) -> bool {
        let s = &self.shared;
        for (widx, w) in s.workers.iter().enumerate() {
            let removed = {
                let mut inbox = lock_ok(&w.inbox);
                let pos = inbox.iter().position(
                    |m| matches!(m, WorkerMsg::Submit(r) if r.id == id),
                );
                pos.and_then(|p| inbox.remove(p))
            };
            if let Some(WorkerMsg::Submit(req)) = removed {
                s.cancelled_in_queue.fetch_add(1, Ordering::Relaxed);
                s.publish(
                    widx,
                    Outcome::Done(Response {
                        id,
                        tokens: Vec::new(),
                        finish: FinishReason::Cancelled,
                        latency_ms: 0.0,
                        ttft_ms: 0.0,
                        prompt_len: req.prompt.len(),
                        choices: Vec::new(),
                    }),
                );
                return true;
            }
        }
        for (widx, w) in s.workers.iter().enumerate() {
            if w.alive.load(Ordering::Acquire) {
                s.enqueue(widx, WorkerMsg::Cancel(id));
            }
        }
        false
    }

    /// Block (Condvar-signaled; no polling) until the request's
    /// terminal outcome arrives or `timeout` elapses.
    pub fn wait_for_outcome(&self, id: RequestId, timeout: Duration) -> Option<Outcome> {
        let s = &self.shared;
        let deadline = Instant::now() + timeout;
        let mut st = lock_ok(&s.completions.state);
        loop {
            if let Some(o) = st.ready.remove(&id) {
                return Some(o);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = s
                .completions
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// Completed / submitted counts (completed includes failures and
    /// cancellations — every accepted request reaches one outcome).
    pub fn progress(&self) -> (usize, usize) {
        let done = lock_ok(&self.shared.completions.state).completed;
        (done, self.shared.submitted.load(Ordering::SeqCst))
    }

    /// Queued + running requests across the pool (gauge).
    pub fn queue_depth(&self) -> usize {
        self.shared.total_in_flight()
    }

    /// Workers currently accepting work.
    pub fn alive_workers(&self) -> usize {
        self.shared
            .workers
            .iter()
            .filter(|w| w.alive.load(Ordering::Acquire))
            .count()
    }

    /// Drain all successful responses accumulated so far.
    pub fn take_responses(&self) -> Vec<Response> {
        let mut st = lock_ok(&self.shared.completions.state);
        let ids: Vec<RequestId> = st
            .ready
            .iter()
            .filter(|(_, o)| matches!(o, Outcome::Done(_)))
            .map(|(&k, _)| k)
            .collect();
        ids.into_iter()
            .filter_map(|k| match st.ready.remove(&k) {
                Some(Outcome::Done(r)) => Some(r),
                _ => None,
            })
            .collect()
    }

    /// Drain all terminal failures accumulated so far.
    pub fn take_failures(&self) -> Vec<RequestError> {
        let mut st = lock_ok(&self.shared.completions.state);
        let ids: Vec<RequestId> = st
            .ready
            .iter()
            .filter(|(_, o)| matches!(o, Outcome::Failed(_)))
            .map(|(&k, _)| k)
            .collect();
        ids.into_iter()
            .filter_map(|k| match st.ready.remove(&k) {
                Some(Outcome::Failed(e)) => Some(e),
                _ => None,
            })
            .collect()
    }

    /// Remove and return the successful response with this id, if
    /// present.
    pub fn take_response_by_id(&self, id: RequestId) -> Option<Response> {
        let mut st = lock_ok(&self.shared.completions.state);
        match st.ready.get(&id) {
            Some(Outcome::Done(_)) => match st.ready.remove(&id) {
                Some(Outcome::Done(r)) => Some(r),
                _ => None,
            },
            _ => None,
        }
    }

    /// Block until every accepted request has a terminal outcome
    /// (Condvar-signaled — no sleep-polling).
    pub fn wait_idle(&self) {
        let s = &self.shared;
        let mut st = lock_ok(&s.completions.state);
        while st.completed < s.submitted.load(Ordering::SeqCst) {
            st = s
                .completions
                .cv
                .wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Live, non-destructive metrics aggregate across the pool: the
    /// shared totals already merged from exited/panicked engines, plus
    /// each worker's last published per-turn snapshot, plus the
    /// router-level robustness counters — the same aggregation
    /// `shutdown` performs, without stopping anything. Safe to call
    /// from any thread while traffic flows; the `{"cmd":"stats"}` admin
    /// frame and the `--metrics-interval` reporter are thin callers.
    pub fn stats_snapshot(&self) -> Metrics {
        let s = &self.shared;
        let mut merged = Metrics::default();
        {
            // metrics → published lock order matches the worker exit and
            // panic paths, so every worker's counters appear exactly
            // once per scrape (either still published, or merged).
            let shared_m = lock_ok(&s.metrics);
            merged.merge(&shared_m);
            for w in &s.workers {
                merged.merge(&lock_ok(&w.published));
            }
        }
        merged.requests_rejected += s.rejected.load(Ordering::Relaxed);
        merged.requests_failed += s.failed.load(Ordering::Relaxed);
        merged.disconnect_aborts += s.cancelled_in_queue.load(Ordering::Relaxed);
        merged.worker_panics += s.worker_panics.load(Ordering::Relaxed);
        merged.worker_restarts += s.worker_restarts.load(Ordering::Relaxed);
        merged.queue_depth_peak = merged
            .queue_depth_peak
            .max(s.queue_depth_peak.load(Ordering::Relaxed));
        merged.affinity_hits += s.affinity_hits.load(Ordering::Relaxed);
        merged.affinity_fallbacks += s.affinity_fallbacks.load(Ordering::Relaxed);
        merged.streams_severed += s.streams_severed.load(Ordering::Relaxed);
        merged.ttft_wire.merge(&lock_ok(&s.ttft_wire));
        merged
    }

    /// Graceful shutdown: stop admitting, let workers drain, merge
    /// their metrics. Blocks until all in-flight work completes.
    pub fn shutdown(self) -> Metrics {
        self.shutdown_inner(None)
    }

    /// Drain-then-abort shutdown: in-flight work gets `drain` to
    /// finish, then survivors are aborted (each still gets a terminal
    /// `Aborted` outcome).
    pub fn shutdown_within(self, drain: Duration) -> Metrics {
        self.shutdown_inner(Some(drain))
    }

    fn shutdown_inner(self, drain: Option<Duration>) -> Metrics {
        let s = &self.shared;
        s.stopping.store(true, Ordering::SeqCst);
        for widx in 0..s.workers.len() {
            s.enqueue(widx, WorkerMsg::Shutdown { abort: false });
        }
        if let Some(d) = drain {
            let deadline = Instant::now() + d;
            let mut st = lock_ok(&s.completions.state);
            while st.completed < s.submitted.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = s
                    .completions
                    .cv
                    .wait_timeout(st, deadline - now)
                    .unwrap_or_else(|e| e.into_inner());
                st = guard;
            }
            let drained = st.completed >= s.submitted.load(Ordering::SeqCst);
            drop(st);
            if !drained {
                for widx in 0..s.workers.len() {
                    s.enqueue(widx, WorkerMsg::Shutdown { abort: true });
                }
            }
        }
        let handles = std::mem::take(&mut *lock_ok(&self.handles));
        for h in handles {
            if h.join().is_err() {
                // A worker died outside its catch_unwind (should not
                // happen): count it instead of silently dropping.
                s.worker_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
        let mut merged = Metrics::default();
        merged.merge(&lock_ok(&s.metrics));
        merged.requests_rejected += s.rejected.load(Ordering::Relaxed);
        merged.requests_failed += s.failed.load(Ordering::Relaxed);
        merged.disconnect_aborts += s.cancelled_in_queue.load(Ordering::Relaxed);
        merged.worker_panics += s.worker_panics.load(Ordering::Relaxed);
        merged.worker_restarts += s.worker_restarts.load(Ordering::Relaxed);
        merged.queue_depth_peak = merged
            .queue_depth_peak
            .max(s.queue_depth_peak.load(Ordering::Relaxed));
        merged.affinity_hits += s.affinity_hits.load(Ordering::Relaxed);
        merged.affinity_fallbacks += s.affinity_fallbacks.load(Ordering::Relaxed);
        merged.streams_severed += s.streams_severed.load(Ordering::Relaxed);
        merged.ttft_wire.merge(&lock_ok(&s.ttft_wire));
        merged
    }
}

/// Per-worker engine: distinct seed, a disjoint id range for any
/// engine-assigned ids, and only this worker's slice of the fault plan.
fn worker_engine(shared: &Shared, widx: usize, faults: FaultPlan) -> Engine {
    let mut wcfg = shared.cfg.clone();
    wcfg.seed = shared.cfg.seed.wrapping_add(widx as u64);
    wcfg.id_offset = ((widx as u64) + 1) << 40;
    // Engine-side queue bound: above the router cap (salvage re-dispatch
    // may overshoot it) but still finite.
    wcfg.scheduler.max_waiting = wcfg
        .scheduler
        .max_waiting
        .min(shared.rcfg.max_queue_per_worker.saturating_mul(2).saturating_add(8));
    wcfg.faults = faults;
    Engine::new(shared.model.clone(), wcfg)
}

fn worker_loop(widx: usize, shared: Arc<Shared>) {
    let me = &shared.workers[widx];
    let mut engine = worker_engine(&shared, widx, shared.cfg.faults.for_worker(widx));
    let mut shutdown = false;
    let mut abort = false;
    loop {
        // Collect inbox messages, blocking only when fully idle.
        let mut msgs: Vec<WorkerMsg> = Vec::new();
        {
            let mut inbox = lock_ok(&me.inbox);
            while inbox.is_empty() && !engine.has_work() && !shutdown {
                inbox = me.cv.wait(inbox).unwrap_or_else(|e| e.into_inner());
            }
            while let Some(m) = inbox.pop_front() {
                msgs.push(m);
            }
        }
        for m in &msgs {
            if let WorkerMsg::Shutdown { abort: a } = m {
                shutdown = true;
                abort = abort || *a;
            }
        }
        // One engine turn — message application plus a step — under
        // catch_unwind so a panic (injected or real) stays contained.
        let turn = catch_unwind(AssertUnwindSafe(|| {
            let mut rejected: Vec<Request> = Vec::new();
            for m in msgs {
                match m {
                    WorkerMsg::Submit(req) => {
                        if let Err(req) = engine.submit_request(req) {
                            rejected.push(req);
                        }
                    }
                    WorkerMsg::Cancel(id) => {
                        engine.cancel(id);
                    }
                    WorkerMsg::Shutdown { .. } => {}
                }
            }
            if abort {
                engine.abort_all();
            }
            if engine.has_work() {
                engine.step();
            }
            (engine.take_finished(), rejected)
        }));
        match turn {
            Ok((done, rejected)) => {
                // Publish this engine's live counters for stats scrapes.
                *lock_ok(&me.published) = engine.metrics.clone();
                for resp in done {
                    shared.publish(widx, Outcome::Done(resp));
                }
                for req in rejected {
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    shared.failed.fetch_add(1, Ordering::Relaxed);
                    shared.publish(
                        widx,
                        Outcome::Failed(RequestError {
                            id: req.id,
                            code: "overloaded",
                            message: "worker queue full".to_string(),
                            retry_after_ms: Some(shared.rcfg.retry_after_ms),
                        }),
                    );
                }
            }
            Err(_) => {
                engine = recover_from_panic(widx, &shared, engine);
                continue;
            }
        }
        if shutdown && !engine.has_work() {
            break;
        }
    }
    // Merge final metrics; count KV blocks the drained engine failed to
    // return (0 in a correct engine — cross-checked against the
    // allocator's debug ledger).
    let leaked = engine.reclaim_and_count_leaks();
    let mut m = engine.metrics.clone();
    m.kv_blocks_leaked += leaked as u64;
    {
        // Lock order metrics → published (stats_snapshot takes the
        // same order): merging into the shared totals and clearing the
        // live slot is atomic w.r.t. scrapes, so no scrape ever sees
        // this worker's counters both merged and published.
        let mut shared_m = lock_ok(&shared.metrics);
        let mut pubm = lock_ok(&me.published);
        shared_m.merge(&m);
        *pubm = Metrics::default();
    }
    me.alive.store(false, Ordering::Release);
}

/// Supervision: contain a worker panic. Salvages the dead engine's
/// requests, restarts the worker in place with a fresh engine (fault
/// plan cleared so deterministic faults fire once), re-dispatches
/// never-decoded requests within the retry budget, and fails the rest
/// with a structured error.
fn recover_from_panic(widx: usize, shared: &Shared, mut engine: Engine) -> Engine {
    let me = &shared.workers[widx];
    me.alive.store(false, Ordering::Release);
    shared.worker_panics.fetch_add(1, Ordering::Relaxed);
    let (retry, dead) = engine.salvage();
    me.in_flight
        .fetch_sub(retry.len() + dead.len(), Ordering::Relaxed);
    let (redispatch, exhausted): (Vec<Request>, Vec<Request>) =
        retry.into_iter().partition(|r| r.attempts < shared.rcfg.max_retries);
    // The panicked engine's counters survive (the old shutdown bug
    // dropped them); re-dispatched requests will be counted as
    // submissions by their new engine, so they leave this snapshot.
    let mut m = engine.metrics.clone();
    m.requests_submitted = m.requests_submitted.saturating_sub(redispatch.len() as u64);
    {
        // Same metrics → published lock order as the worker exit path.
        let mut shared_m = lock_ok(&shared.metrics);
        let mut pubm = lock_ok(&me.published);
        shared_m.merge(&m);
        *pubm = Metrics::default();
    }
    // Dump the dead engine's flight-recorder ring before discarding it:
    // the span timeline leading up to the panic is exactly what a
    // post-mortem needs.
    if let Some(path) = engine.recorder.dump_panic(widx) {
        eprintln!("trace: worker {widx} flight recorder dumped to {}", path.display());
    }
    drop(engine); // pool/radix state is untrusted — discard wholesale
    let fresh = worker_engine(shared, widx, FaultPlan::none());
    shared.worker_restarts.fetch_add(1, Ordering::Relaxed);
    me.alive.store(true, Ordering::Release);
    for mut req in redispatch {
        req.attempts += 1;
        if let Err(req) = shared.dispatch(req) {
            shared.fail(
                req.id,
                "worker_failed",
                "worker panicked and no live worker could take the retry".to_string(),
            );
        }
    }
    for req in exhausted {
        shared.fail(
            req.id,
            "worker_failed",
            "worker panicked; retry budget exhausted".to_string(),
        );
    }
    for (req, emitted) in dead {
        // Progress a replay could not reproduce: the terminal error
        // carries the emitted-token count so a streaming client knows
        // exactly where its stream was truncated.
        shared.fail(
            req.id,
            "worker_failed",
            format!("worker panicked mid-generation ({emitted} tokens emitted)"),
        );
    }
    fresh
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_breaks_ties_by_lowest_index() {
        // Equal loads → lowest worker index, regardless of iteration
        // order, so routing replays identically under FaultPlans.
        assert_eq!(least_loaded([(0, 3), (1, 3), (2, 3)].into_iter()), Some(0));
        assert_eq!(least_loaded([(2, 3), (1, 3), (0, 3)].into_iter()), Some(0));
        assert_eq!(least_loaded([(2, 1), (1, 1), (0, 4)].into_iter()), Some(1));
        // Strictly-lower load still beats a lower index.
        assert_eq!(least_loaded([(0, 5), (3, 2), (1, 2)].into_iter()), Some(1));
        assert_eq!(least_loaded(std::iter::empty()), None);
    }

    #[test]
    fn sketch_routes_shared_prefixes_and_stays_bounded() {
        let mut sk = PrefixSketch::default();
        let prompt: Vec<u32> = (0..100).collect();
        assert_eq!(sk.candidate(&prompt), None);
        sk.note(&prompt, 2);
        // Identical prompt and a same-prefix extension both resolve.
        assert_eq!(sk.candidate(&prompt), Some(2));
        let mut longer = prompt.clone();
        longer.extend([900, 901, 902]);
        assert_eq!(sk.candidate(&longer), Some(2));
        // A prompt diverging before every grain does not.
        let other: Vec<u32> = (500..600).collect();
        assert_eq!(sk.candidate(&other), None);
        // Newest note wins, and the map stays bounded under churn.
        sk.note(&prompt, 0);
        assert_eq!(sk.candidate(&prompt), Some(0));
        for i in 0..(SKETCH_CAP as u32 * 4) {
            sk.note(&[i, i + 1, i + 2, i + 3], 1);
        }
        assert!(sk.map.len() <= SKETCH_CAP + SKETCH_GRAINS.len());
    }
}
