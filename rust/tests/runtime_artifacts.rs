//! Runtime integration: load the AOT HLO artifacts on the PJRT CPU
//! client, execute them, and cross-check against the native rust forward.
//! This proves all three layers compose: Pallas kernel (L1) → JAX model
//! (L2) → rust execution (L3), Python nowhere at run time.

use hsr_attn::model::Model;
use hsr_attn::runtime::{Buffer, Runtime};
use hsr_attn::util::tensor_io::TensorBundle;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Runnable only when the artifacts exist AND the real PJRT client is
/// compiled in; without the `pjrt` feature `Runtime::new` is a stub that
/// always errors, so these tests must skip even if artifacts are present.
fn have_artifacts() -> bool {
    cfg!(feature = "pjrt") && artifacts_dir().join("manifest.json").exists()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn decode_step_artifact_matches_golden_and_native() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).expect("runtime");
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let exe = rt.load("decode_step_small").expect("compile decode_step");

    let golden = TensorBundle::load(&dir.join("golden_small")).unwrap();
    let tokens: Vec<u32> = golden
        .get("tokens_a")
        .unwrap()
        .data
        .iter()
        .map(|&t| t as u32)
        .collect();
    let want = &golden.get("decode_logits").unwrap().data;
    let n_ctx = golden.meta.get("n_ctx").and_then(|v| v.as_usize()).unwrap();
    let pos = golden.meta.get("decode_pos").and_then(|v| v.as_usize()).unwrap();

    // Build the cache by running the decode-step artifact over the first
    // `pos` tokens (pure rust + PJRT; no Python).
    let model = Model::load_named(&dir, "small").unwrap();
    let (l, h, dh) = (model.cfg.n_layers, model.cfg.n_heads, model.cfg.d_head);
    let cache_shape = vec![l, h, n_ctx, dh];
    let cache_len: usize = cache_shape.iter().product();
    let mut k_cache = vec![0f32; cache_len];
    let mut v_cache = vec![0f32; cache_len];
    for p in 0..=pos {
        let outs = rt
            .execute(
                &exe,
                &[
                    Buffer::scalar_i32(tokens[p] as i32),
                    Buffer::scalar_i32(p as i32),
                    Buffer::f32(k_cache.clone(), cache_shape.clone()),
                    Buffer::f32(v_cache.clone(), cache_shape.clone()),
                ],
            )
            .expect("execute decode step");
        assert_eq!(outs.len(), 3, "decode step returns (logits, new_k, new_v)");
        let (logits, new_k, new_v) = (&outs[0], &outs[1], &outs[2]);
        // Write new k/v rows into the cache at position p.
        for layer in 0..l {
            for head in 0..h {
                let src = (layer * h + head) * dh;
                let dst = ((layer * h + head) * n_ctx + p) * dh;
                k_cache[dst..dst + dh].copy_from_slice(&new_k[src..src + dh]);
                v_cache[dst..dst + dh].copy_from_slice(&new_v[src..src + dh]);
            }
        }
        if p == pos {
            let err = max_abs_diff(logits, want);
            assert!(err < 2e-3, "PJRT decode logits deviate from golden by {err}");
            // And against the native rust forward.
            let native = model.forward_full(&tokens[..=pos]);
            let vocab = model.cfg.vocab;
            let err2 = max_abs_diff(logits, &native[pos * vocab..(pos + 1) * vocab]);
            assert!(err2 < 3e-3, "PJRT vs native deviates by {err2}");
        }
    }
}

#[test]
fn prefill_artifact_matches_native() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("prefill_small").expect("compile prefill");
    let spec = &rt.manifest.hlo["prefill_small"];
    let t = spec.inputs[0].shape[0];
    // Deterministic ASCII prompt padded to the artifact length.
    let text = "the merchant carries copper coins by the river. ";
    let mut tokens: Vec<i32> = text.bytes().map(|b| b as i32).collect();
    while tokens.len() < t {
        tokens.push(b' ' as i32);
    }
    tokens.truncate(t);
    let outs = rt
        .execute(&exe, &[Buffer::i32(tokens.clone(), vec![t])])
        .expect("execute prefill");
    assert_eq!(outs.len(), 3);
    let logits = &outs[0];
    let model = Model::load_named(&dir, "small").unwrap();
    let native = model.forward_full(&tokens.iter().map(|&x| x as u32).collect::<Vec<_>>());
    let err = max_abs_diff(logits, &native);
    assert!(err < 3e-3, "prefill artifact vs native deviates by {err}");
}

#[test]
fn masked_softmax_kernel_artifact_runs() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("masked_softmax_attn").expect("compile kernel");
    let spec = &rt.manifest.hlo["masked_softmax_attn"];
    let heads = spec.attrs["heads"] as usize;
    let r_max = spec.attrs["r_max"] as usize;
    let dh = spec.attrs["d_head"] as usize;

    let mut rng = hsr_attn::util::rng::Rng::new(7);
    let q = rng.gaussian_vec_f32(heads * dh, 1.0);
    let kg = rng.gaussian_vec_f32(heads * r_max * dh, 1.0);
    let vg = rng.gaussian_vec_f32(heads * r_max * dh, 1.0);
    let counts: Vec<i32> = (0..heads).map(|i| (17 * (i + 1)) as i32).collect();
    let outs = rt
        .execute(
            &exe,
            &[
                Buffer::f32(q.clone(), vec![heads, dh]),
                Buffer::f32(kg.clone(), vec![heads, r_max, dh]),
                Buffer::f32(vg.clone(), vec![heads, r_max, dh]),
                Buffer::i32(counts.clone(), vec![heads]),
            ],
        )
        .expect("execute masked softmax kernel");
    let got = &outs[0];
    assert_eq!(got.len(), heads * dh);
    // Cross-check against the rust attention math per head.
    let mut buf = Vec::new();
    for hd in 0..heads {
        let qh = &q[hd * dh..(hd + 1) * dh];
        let keys = &kg[hd * r_max * dh..(hd + 1) * r_max * dh];
        let vals = &vg[hd * r_max * dh..(hd + 1) * r_max * dh];
        let idx: Vec<u32> = (0..counts[hd] as u32).collect();
        let mut want = vec![0f32; dh];
        hsr_attn::attention::softmax::softmax_attention_row_subset(
            qh, keys, vals, dh, &idx, &mut buf, &mut want,
        );
        let err = max_abs_diff(&got[hd * dh..(hd + 1) * dh], &want);
        assert!(err < 1e-4, "head {hd}: pallas-via-PJRT vs rust deviates {err}");
    }
}

#[test]
fn masked_relu_kernel_artifact_runs() {
    if !have_artifacts() {
        return;
    }
    let dir = artifacts_dir();
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("masked_relu_attn").expect("compile kernel");
    let spec = &rt.manifest.hlo["masked_relu_attn"];
    let heads = spec.attrs["heads"] as usize;
    let r_max = spec.attrs["r_max"] as usize;
    let dh = spec.attrs["d_head"] as usize;
    let alpha = spec.attrs["alpha"] as u32;
    let bias = spec.attrs["bias"] as f32;

    let mut rng = hsr_attn::util::rng::Rng::new(8);
    let q = rng.gaussian_vec_f32(heads * dh, 1.0);
    let kg = rng.gaussian_vec_f32(heads * r_max * dh, 1.0);
    let vg = rng.gaussian_vec_f32(heads * r_max * dh, 1.0);
    let counts: Vec<i32> = (0..heads).map(|i| (31 * (i + 1)) as i32).collect();
    let outs = rt
        .execute(
            &exe,
            &[
                Buffer::f32(q.clone(), vec![heads, dh]),
                Buffer::f32(kg.clone(), vec![heads, r_max, dh]),
                Buffer::f32(vg.clone(), vec![heads, r_max, dh]),
                Buffer::i32(counts.clone(), vec![heads]),
            ],
        )
        .expect("execute masked relu kernel");
    let got = &outs[0];
    let mut buf = Vec::new();
    for hd in 0..heads {
        let qh = &q[hd * dh..(hd + 1) * dh];
        let keys = &kg[hd * r_max * dh..(hd + 1) * r_max * dh];
        let vals = &vg[hd * r_max * dh..(hd + 1) * r_max * dh];
        let idx: Vec<u32> = (0..counts[hd] as u32).collect();
        let mut want = vec![0f32; dh];
        hsr_attn::attention::relu::relu_attention_row_sparse(
            qh, keys, vals, dh, alpha, bias, &idx, &mut buf, &mut want,
        );
        let err = max_abs_diff(&got[hd * dh..(hd + 1) * dh], &want);
        assert!(err < 1e-4, "head {hd}: relu kernel deviates {err}");
    }
}
