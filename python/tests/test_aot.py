"""AOT pipeline: weight-bundle format, golden vectors, HLO text emission.

Runs the full exporter in --fast mode into a temp dir (slow-ish but the
whole L2→L3 contract depends on it)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


@pytest.fixture(scope="module")
def fast_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--models", "mini", "--hlo-model", "mini", "--fast"],
        cwd=os.path.join(REPO, "python"),
        env=env,
        check=True,
        timeout=900,
    )
    return out


def test_manifest_contents(fast_artifacts):
    with open(fast_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert "mini" in manifest["models"]
    cfg = manifest["models"]["mini"]
    assert cfg["d_model"] == 64 and cfg["vocab"] == 256
    assert "decode_step_mini" in manifest["hlo"]
    assert "masked_softmax_attn" in manifest["hlo"]


def test_weight_bundle_roundtrip(fast_artifacts):
    with open(fast_artifacts / "model_mini.json") as f:
        man = json.load(f)
    blob = np.fromfile(fast_artifacts / "model_mini.bin", dtype="<f4")
    assert man["dtype"] == "f32"
    assert man["byte_len"] == blob.nbytes
    # Every tensor fits and the embedding has the right shape.
    emb = man["tensors"]["tok_emb"]
    assert emb["shape"] == [256, 64]
    for name, spec in man["tensors"].items():
        numel = int(np.prod(spec["shape"]))
        assert spec["offset"] + numel <= len(blob), name


def test_golden_vectors_present(fast_artifacts):
    with open(fast_artifacts / "golden_mini.json") as f:
        man = json.load(f)
    t = man["tensors"]
    assert t["logits_a"]["shape"] == [32, 256]
    assert t["decode_logits"]["shape"] == [256]
    assert man["decode_pos"] == 31


def test_hlo_text_is_parseable_hlo(fast_artifacts):
    txt = (fast_artifacts / "decode_step_mini.hlo.txt").read_text()
    assert txt.startswith("HloModule"), txt[:80]
    assert "ENTRY" in txt
    # 64-bit ids would start around 4e9; text form keeps small ids.
    txt2 = (fast_artifacts / "masked_softmax_attn.hlo.txt").read_text()
    assert txt2.startswith("HloModule")


def test_train_log_has_decreasing_loss(fast_artifacts):
    with open(fast_artifacts / "train_log.json") as f:
        log = json.load(f)
    losses = log["mini"]
    assert losses[-1] < losses[0]
