#!/usr/bin/env bash
# Repo verification: format, build, tests, and the kernel perf smoke run.
#
# Usage: scripts/verify.sh [--no-bench]
#
# The bench step runs only the kernel section of benches/hsr_structures.rs
# and emits BENCH_kernels.json at the repo root (before/after ns-per-row
# for dot, scores_into, the softmax row, and end-to-end prefill), so the
# perf trajectory across PRs is machine-readable.

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== kernel perf smoke (BENCH_kernels.json) =="
    cargo bench --bench hsr_structures -- --kernels-only
    echo "report: $(cd .. && pwd)/BENCH_kernels.json"
fi

echo "verify: OK"
