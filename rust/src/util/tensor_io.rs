//! Weight / tensor blob I/O shared with the Python compile path.
//!
//! Format (written by `python/compile/aot.py`, read here):
//!   <name>.json       manifest: {"tensors": {name: {"offset": o, "shape": [..]}},
//!                                "dtype": "f32", "byte_len": N, ...extra}
//!   <name>.bin        all tensors concatenated as little-endian f32.
//!
//! This avoids a dependency on npy/npz/safetensors parsers while staying
//! trivially writable from numpy (`arr.astype('<f4').tobytes()`).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// A named f32 tensor with shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major 2-D accessor (debug-checked).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// View row i of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        &self.data[i * c..(i + 1) * c]
    }
}

/// A bundle of named tensors plus free-form metadata.
#[derive(Debug, Clone, Default)]
pub struct TensorBundle {
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: BTreeMap<String, Json>,
}

impl TensorBundle {
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor '{name}' not found in bundle"))
    }

    /// Load from `<stem>.json` + `<stem>.bin`.
    pub fn load(stem: &Path) -> Result<TensorBundle> {
        let json_path = stem.with_extension("json");
        let bin_path = stem.with_extension("bin");
        let manifest_text = std::fs::read_to_string(&json_path)
            .with_context(|| format!("reading {}", json_path.display()))?;
        let manifest = Json::parse(&manifest_text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", json_path.display()))?;
        let dtype = manifest.req_str("dtype")?;
        if dtype != "f32" {
            bail!("unsupported dtype '{dtype}' (only f32)");
        }
        let bytes = std::fs::read(&bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("blob length {} not a multiple of 4", bytes.len());
        }
        if let Some(expect) = manifest.get("byte_len").and_then(|v| v.as_usize()) {
            if expect != bytes.len() {
                bail!("blob length {} != manifest byte_len {}", bytes.len(), expect);
            }
        }
        let all: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut bundle = TensorBundle::default();
        let tensors = manifest
            .get("tensors")
            .context("manifest missing 'tensors'")?;
        let Json::Obj(map) = tensors else {
            bail!("'tensors' is not an object");
        };
        for (name, spec) in map {
            let offset = spec.req_usize("offset")?;
            let shape: Vec<usize> = spec
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().context("bad shape entry"))
                .collect::<Result<_>>()?;
            let numel: usize = shape.iter().product();
            if offset + numel > all.len() {
                bail!(
                    "tensor '{name}' (offset {offset}, numel {numel}) exceeds blob ({})",
                    all.len()
                );
            }
            bundle.insert(name, Tensor::new(shape, all[offset..offset + numel].to_vec()));
        }
        if let Json::Obj(m) = &manifest {
            for (k, v) in m {
                if k != "tensors" && k != "dtype" && k != "byte_len" {
                    bundle.meta.insert(k.clone(), v.clone());
                }
            }
        }
        Ok(bundle)
    }

    /// Save to `<stem>.json` + `<stem>.bin`.
    pub fn save(&self, stem: &Path) -> Result<()> {
        let mut blob: Vec<u8> = Vec::new();
        let mut tensors = Json::obj();
        for (name, t) in &self.tensors {
            let offset = blob.len() / 4;
            for &x in &t.data {
                blob.extend_from_slice(&x.to_le_bytes());
            }
            let mut spec = Json::obj();
            spec.set("offset", offset.into());
            spec.set(
                "shape",
                Json::Arr(t.shape.iter().map(|&s| Json::from(s)).collect()),
            );
            tensors.set(name, spec);
        }
        let mut manifest = Json::obj();
        manifest.set("dtype", "f32".into());
        manifest.set("byte_len", blob.len().into());
        manifest.set("tensors", tensors);
        for (k, v) in &self.meta {
            manifest.set(k, v.clone());
        }
        if let Some(dir) = stem.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(stem.with_extension("json"), manifest.to_string())?;
        std::fs::write(stem.with_extension("bin"), blob)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("hsr_tio_{}", std::process::id()));
        let stem = dir.join("weights");
        let mut b = TensorBundle::default();
        b.insert("w", Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        b.insert("bias", Tensor::new(vec![3], vec![-1.0, 0.5, 0.25]));
        b.meta.insert("d_model".into(), Json::from(3usize));
        b.save(&stem).unwrap();
        let r = TensorBundle::load(&stem).unwrap();
        assert_eq!(r.get("w").unwrap(), b.get("w").unwrap());
        assert_eq!(r.get("bias").unwrap(), b.get("bias").unwrap());
        assert_eq!(r.meta.get("d_model").unwrap().as_usize(), Some(3));
        assert_eq!(r.get("w").unwrap().at2(1, 2), 6.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let b = TensorBundle::default();
        assert!(b.get("nope").is_err());
    }

    #[test]
    fn corrupt_manifest_errors() {
        let dir = std::env::temp_dir().join(format!("hsr_tio_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.json"), "{not json").unwrap();
        std::fs::write(dir.join("x.bin"), [0u8; 4]).unwrap();
        assert!(TensorBundle::load(&dir.join("x")).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_blob_errors() {
        let dir = std::env::temp_dir().join(format!("hsr_tio_tr_{}", std::process::id()));
        let stem = dir.join("w");
        let mut b = TensorBundle::default();
        b.insert("w", Tensor::new(vec![4], vec![1.0; 4]));
        b.save(&stem).unwrap();
        // Truncate the blob behind the manifest's back.
        std::fs::write(stem.with_extension("bin"), [0u8; 8]).unwrap();
        assert!(TensorBundle::load(&stem).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn row_view() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }
}
